"""Serving engine: continuous batching semantics + decode fidelity +
int8-KV path + slot-lifecycle state machine + per-token streaming."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import lm
from repro.serve.engine import Engine, Request

KEY = jax.random.PRNGKey(0)


def _setup(kv_quant=False):
    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=64, vocab=64)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    params = lm.init_params(KEY, cfg)
    return cfg, params


def test_engine_matches_manual_decode():
    cfg, params = _setup()
    prompt = np.arange(1, 9, dtype=np.int32)
    eng = Engine(params, cfg, batch_slots=2, cache_len=64)
    (done,) = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=6)])

    # manual greedy loop
    logits, caches = lm.prefill(params, cfg, jnp.asarray(prompt[None]),
                                cache_len=64)
    toks = [int(jnp.argmax(logits[0, 0]))]
    pos = len(prompt)
    for _ in range(5):
        l, caches = lm.decode_step(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray([pos], jnp.int32), caches)
        toks.append(int(jnp.argmax(l[0, 0])))
        pos += 1
    assert done.out_tokens == toks


def test_continuous_batching_more_requests_than_slots():
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=(5 + i,))
                    .astype(np.int32), max_new_tokens=4)
            for i in range(5)]
    eng = Engine(params, cfg, batch_slots=2, cache_len=32)
    done = eng.run(list(reqs))
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out_tokens) == 4 for r in done)


def test_slot_isolation():
    """A sequence's output must not depend on its slot neighbors."""
    cfg, params = _setup()
    p1 = np.arange(1, 7, dtype=np.int32)
    p2 = np.arange(30, 40, dtype=np.int32)
    solo = Engine(params, cfg, batch_slots=1, cache_len=64).run(
        [Request(rid=0, prompt=p1, max_new_tokens=5)])[0].out_tokens
    together = Engine(params, cfg, batch_slots=2, cache_len=64).run(
        [Request(rid=0, prompt=p1, max_new_tokens=5),
         Request(rid=1, prompt=p2, max_new_tokens=5)])
    got = [r.out_tokens for r in together if r.rid == 0][0]
    assert got == solo


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_slot_lifecycle_state_machine(seed):
    """FREE→PREFILL→DECODE→FREE invariants under randomized EOS
    patterns (DESIGN.md §11): an occupied slot keeps its request until
    that request retires; a slot is refilled only after it was observed
    FREE at the start of a step (no refill into an occupied slot, no
    double-free); every request resolves exactly once."""
    cfg, params = _setup()
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 64, size=(int(
                        rng.integers(3, 12)),)).astype(np.int32),
                    max_new_tokens=int(rng.integers(1, 7)),
                    # random EOS id: some streams stop early, some at
                    # admission, some never hit it
                    eos_id=int(rng.integers(0, 64)))
            for i in range(9)]
    eng = Engine(params, cfg, batch_slots=3, cache_len=32)
    for r in reqs:
        eng.submit(r)

    retired, seen_done = [], set()
    prev_occ = [None] * eng.B                    # rid or None per slot
    while eng.has_work():
        finished = eng.step()
        occ = [r.rid if r is not None else None for r in eng.slot_req]
        fin = {r.rid for r in finished}
        for s in range(eng.B):
            if occ[s] is not None and occ[s] != prev_occ[s]:
                # admission happens at step START: a slot can only take
                # a new request if it was FREE before this step
                assert prev_occ[s] is None, \
                    (s, prev_occ[s], occ[s], "refill into occupied slot")
            if prev_occ[s] is not None and occ[s] != prev_occ[s]:
                # a slot only empties/swaps by retiring its request
                assert prev_occ[s] in fin, (s, prev_occ[s])
        # occupancy is exclusive: one slot per live request
        live = [o for o in occ if o is not None]
        assert len(live) == len(set(live))
        for r in finished:
            assert r.done and r.status == "done"
            assert r.rid not in seen_done, (r.rid, "double retire")
            seen_done.add(r.rid)
            assert r.rid not in live, (r.rid, "retired but still in slot")
        retired.extend(finished)
        prev_occ = occ
    assert sorted(r.rid for r in retired) == list(range(len(reqs)))
    assert eng.slot_req == [None] * eng.B        # all slots back to FREE
    assert eng.stats["admitted"] == len(reqs)
    for r in retired:                            # EOS semantics honored
        if r.eos_id in r.out_tokens:
            assert r.out_tokens.index(r.eos_id) == len(r.out_tokens) - 1
        else:
            assert len(r.out_tokens) == r.max_new_tokens


@pytest.mark.slow
def test_engine_stream_iterator_and_callback():
    """Engine.stream yields (rid, token) per sampled token in order;
    run(on_token=...) sees the identical event sequence; both match
    Request.out_tokens and the non-streaming engine bit-for-bit."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    mk = lambda: [Request(rid=i,
                          prompt=rng.integers(0, 64, size=(5 + i,))
                          .astype(np.int32),
                          max_new_tokens=4) for i in range(4)]
    rng = np.random.default_rng(3)
    base = {r.rid: r.out_tokens for r in Engine(
        params, cfg, batch_slots=2, cache_len=64).run(mk())}

    rng = np.random.default_rng(3)
    reqs = mk()
    eng = Engine(params, cfg, batch_slots=2, cache_len=64)
    events = list(eng.stream(reqs))
    per = {}
    for rid, tok in events:
        per.setdefault(rid, []).append(tok)
    assert per == base
    assert {r.rid: r.out_tokens for r in reqs} == base
    assert eng.on_token is None                  # sink detached

    rng = np.random.default_rng(3)
    cb_events = []
    Engine(params, cfg, batch_slots=2, cache_len=64).run(
        mk(), on_token=lambda req, tok: cb_events.append((req.rid, tok)))
    assert cb_events == events


def test_int8_kv_engine_agrees_on_greedy_tokens():
    cfg, params = _setup()
    cfg8 = dataclasses.replace(cfg, kv_quant=True)
    prompt = np.arange(2, 12, dtype=np.int32)
    a = Engine(params, cfg, batch_slots=1, cache_len=64).run(
        [Request(rid=0, prompt=prompt, max_new_tokens=8)])[0].out_tokens
    b = Engine(params, cfg8, batch_slots=1, cache_len=64).run(
        [Request(rid=0, prompt=prompt, max_new_tokens=8)])[0].out_tokens
    # int8 KV: logits differ at ~1e-3; greedy tokens should rarely flip
    agree = sum(int(x == y) for x, y in zip(a, b)) / len(a)
    assert agree >= 0.75
