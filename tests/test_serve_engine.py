"""Serving engine: continuous batching semantics + decode fidelity +
int8-KV path."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import lm
from repro.serve.engine import Engine, Request

KEY = jax.random.PRNGKey(0)


def _setup(kv_quant=False):
    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=64, vocab=64)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    params = lm.init_params(KEY, cfg)
    return cfg, params


def test_engine_matches_manual_decode():
    cfg, params = _setup()
    prompt = np.arange(1, 9, dtype=np.int32)
    eng = Engine(params, cfg, batch_slots=2, cache_len=64)
    (done,) = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=6)])

    # manual greedy loop
    logits, caches = lm.prefill(params, cfg, jnp.asarray(prompt[None]),
                                cache_len=64)
    toks = [int(jnp.argmax(logits[0, 0]))]
    pos = len(prompt)
    for _ in range(5):
        l, caches = lm.decode_step(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray([pos], jnp.int32), caches)
        toks.append(int(jnp.argmax(l[0, 0])))
        pos += 1
    assert done.out_tokens == toks


def test_continuous_batching_more_requests_than_slots():
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=(5 + i,))
                    .astype(np.int32), max_new_tokens=4)
            for i in range(5)]
    eng = Engine(params, cfg, batch_slots=2, cache_len=32)
    done = eng.run(list(reqs))
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out_tokens) == 4 for r in done)


def test_slot_isolation():
    """A sequence's output must not depend on its slot neighbors."""
    cfg, params = _setup()
    p1 = np.arange(1, 7, dtype=np.int32)
    p2 = np.arange(30, 40, dtype=np.int32)
    solo = Engine(params, cfg, batch_slots=1, cache_len=64).run(
        [Request(rid=0, prompt=p1, max_new_tokens=5)])[0].out_tokens
    together = Engine(params, cfg, batch_slots=2, cache_len=64).run(
        [Request(rid=0, prompt=p1, max_new_tokens=5),
         Request(rid=1, prompt=p2, max_new_tokens=5)])
    got = [r.out_tokens for r in together if r.rid == 0][0]
    assert got == solo


def test_int8_kv_engine_agrees_on_greedy_tokens():
    cfg, params = _setup()
    cfg8 = dataclasses.replace(cfg, kv_quant=True)
    prompt = np.arange(2, 12, dtype=np.int32)
    a = Engine(params, cfg, batch_slots=1, cache_len=64).run(
        [Request(rid=0, prompt=prompt, max_new_tokens=8)])[0].out_tokens
    b = Engine(params, cfg8, batch_slots=1, cache_len=64).run(
        [Request(rid=0, prompt=prompt, max_new_tokens=8)])[0].out_tokens
    # int8 KV: logits differ at ~1e-3; greedy tokens should rarely flip
    agree = sum(int(x == y) for x, y in zip(a, b)) / len(a)
    assert agree >= 0.75
