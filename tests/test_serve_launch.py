"""launch/serve.py argument validation: malformed --mesh and
--ranks/--buckets misuse must fail with a clear usage error at parse
time, not as a cryptic make_mesh / submesh shape failure downstream."""
import jax
import pytest

from repro.launch.serve import check_ranks, parse_buckets, parse_mesh


@pytest.mark.parametrize("spec", ["2", "a,b", "1,2,3", ",2", "2,"])
def test_parse_mesh_rejects_malformed_spec(spec, monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "")       # parse_mesh may touch it
    with pytest.raises(SystemExit, match="--mesh expects 'DP,TP'"):
        parse_mesh(spec)


@pytest.mark.parametrize("spec", ["0,2", "2,0", "0,0"])
def test_parse_mesh_rejects_zero_axes(spec, monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "")
    with pytest.raises(SystemExit, match="must both be >= 1"):
        parse_mesh(spec)


def test_parse_mesh_none_and_valid(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "")
    assert parse_mesh(None) is None
    assert parse_mesh("") is None
    mesh = parse_mesh("1,1")                  # fits any device count
    assert dict(mesh.shape) == {"data": 1, "model": 1}


def test_check_ranks_exceeding_dp_size_is_a_clear_error():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(SystemExit, match="exceeds the mesh's DP size"):
        check_ranks(2, mesh)


def test_check_ranks_accepts_match_and_meshless():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    check_ranks(None, mesh)                   # omitted: mesh decides
    check_ranks(1, mesh)                      # equals DP size: fine
    check_ranks(7, None)                      # meshless: any count


def test_parse_buckets_forms():
    assert parse_buckets(None, 512) is None
    assert parse_buckets("", 512) is None
    assert parse_buckets("4", 512) == (64, 128, 256, 512)
    assert parse_buckets("32,64,128", 512) == (32, 64, 128)
    for bad in ("x", "0", "8,0", "-1"):
        with pytest.raises(SystemExit, match="--buckets"):
            parse_buckets(bad, 512)
    # a bucket beyond the cache could never admit: loud error, not a
    # silent fall-back to exact shapes
    with pytest.raises(SystemExit, match="cache-len"):
        parse_buckets("128,256", 64)


def _main_exits(argv, match, monkeypatch):
    import sys

    from repro.launch import serve
    monkeypatch.setattr(sys, "argv", ["serve"] + argv)
    with pytest.raises(SystemExit, match=match):
        serve.main()


def test_frontend_flags_validate_before_model_build(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "")
    _main_exits(["--hosts", "0"], "--hosts must be >= 1", monkeypatch)
    _main_exits(["--hosts", "2", "--mesh", "1,1"],
                "in-process hosts without a mesh", monkeypatch)
    _main_exits(["--chaos", "kill:0@3"], "add --hosts N", monkeypatch)
    _main_exits(["--hosts", "2", "--chaos", "explode:0@3"],
                "--chaos", monkeypatch)


def test_engine_rejects_buckets_beyond_cache_len():
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.models import lm
    from repro.serve.engine import Engine

    cfg = reduced(get_config("qwen3-32b"), layers=1, d_model=32,
                  vocab=32)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="cache_len"):
        Engine(params, cfg, batch_slots=1, cache_len=32,
               buckets=(16, 64))


def _valid_kv(**over):
    """validate_kv_flags kwargs for a healthy paged+spec config;
    override per-case to isolate the rule under test."""
    from repro.launch.serve import validate_kv_flags
    kw = dict(kv_pages=24, kv_watermark=0.9, kv_share=True,
              kv_share_min_pages=1, int8_kv=False, draft_sparsity=0.75,
              draft_k=4, draft_int8=False, kv_dedup_every=64,
              cache_len=256)
    kw.update(over)
    return validate_kv_flags(**kw)


def test_validate_kv_flags_accepts_healthy_combinations():
    _valid_kv()                                    # paged+share+spec
    _valid_kv(draft_sparsity=None, kv_dedup_every=0)
    _valid_kv(kv_pages=None, kv_share=False, draft_sparsity=None,
              kv_dedup_every=0)                    # contiguous engine
    _valid_kv(kv_share=False, kv_dedup_every=0,
              int8_kv=False, draft_int8=True)      # int8 drafter pack


@pytest.mark.parametrize("over,match", [
    (dict(kv_watermark=0.0), "--kv-watermark"),
    (dict(kv_watermark=1.5), "--kv-watermark"),
    (dict(kv_pages=0), "--kv-pages must be >= 1"),
    (dict(kv_pages=None, draft_sparsity=None, kv_dedup_every=0),
     "--kv-share requires --kv-pages"),
    (dict(int8_kv=True, draft_sparsity=None, kv_dedup_every=0),
     "--kv-share is incompatible with --int8-kv"),
    (dict(kv_share_min_pages=0), "--kv-share-min-pages"),
    (dict(kv_pages=None, kv_share=False, kv_dedup_every=0),
     "--draft-sparsity requires --kv-pages"),
    (dict(kv_share=False, int8_kv=True, kv_dedup_every=0),
     "--draft-sparsity is incompatible with --int8-kv"),
    (dict(draft_sparsity=1.0), "--draft-sparsity must lie in"),
    (dict(draft_sparsity=-0.5), "--draft-sparsity must lie in"),
    (dict(draft_k=0), "--draft-k must be >= 1"),
    (dict(draft_k=400), "shrink --draft-k"),
    (dict(draft_sparsity=None, draft_int8=True, kv_dedup_every=0),
     "add --draft-sparsity"),
    (dict(kv_dedup_every=-1), "--kv-dedup-every must be >= 0"),
    (dict(kv_share=False), "--kv-dedup-every requires"),
    (dict(kv_pages=None, kv_share=False, draft_sparsity=None),
     "--kv-dedup-every requires"),
])
def test_validate_kv_flags_rejects_bad_combinations(over, match):
    with pytest.raises(SystemExit, match=match):
        _valid_kv(**over)


def test_draft_flags_validate_identically_on_all_three_paths(
        monkeypatch):
    """The same bad --draft-* combo must exit with the same message
    whether the launcher would build a frontend, a scheduler, or a
    solo engine — the whole point of the consolidated validator."""
    monkeypatch.setenv("XLA_FLAGS", "")
    for path in ([], ["--scheduler"], ["--hosts", "2"]):
        _main_exits(path + ["--draft-sparsity", "0.75"],
                    "--draft-sparsity requires --kv-pages", monkeypatch)
        _main_exits(path + ["--kv-pages", "16", "--int8-kv",
                            "--draft-sparsity", "0.75"],
                    "incompatible with --int8-kv", monkeypatch)
        _main_exits(path + ["--draft-int8"],
                    "add --draft-sparsity", monkeypatch)
        _main_exits(path + ["--kv-pages", "16", "--kv-dedup-every",
                            "32"],
                    "--kv-dedup-every requires", monkeypatch)
