"""Tests for the static analysis suite (tools/analyze, DESIGN.md §15).

Each pass is proven twice against the seeded fixture modules under
tests/fixtures/analyze/: the *_bad.py module must produce its seeded
finding (true positive), the *_clean.py twin must produce zero findings
(clean negative).  The packed pass is exercised on real containers from
core.deploy, corrupted field-by-field.  Finally the full repo run must
be clean — the --strict CI gate."""
import os
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from tools.analyze import PASS_NAMES, run_all  # noqa: E402
from tools.analyze import (concurrency, packed, recompile, shim,  # noqa: E402
                           telemetry, trace_safety)
from tools.analyze.common import Finding, load_baseline, \
    write_baseline  # noqa: E402
from tools.analyze.rules import RULES  # noqa: E402

FIX = os.path.join(REPO, "tests", "fixtures", "analyze")


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# pass 1: trace safety
# ---------------------------------------------------------------------------

def test_trace_safety_fixture_true_positives():
    found = trace_safety.run(FIX, subdirs=("",), root_dirs=("",))
    by_rule = {}
    for f in found:
        by_rule.setdefault(f.rule, []).append(f)
    assert "TRACE-BRANCH" in by_rule, found
    assert "TRACE-COERCE" in by_rule, found
    assert "TRACE-HOSTCALL" in by_rule, found
    # every finding lands in the bad module, none in the clean twin
    assert all(f.path.endswith("trace_bad.py") for f in found), found


def test_trace_safety_clean_twin_silent():
    found = trace_safety.run(FIX, subdirs=("trace_clean.py",),
                             root_dirs=("",))
    assert found == [], found


def test_trace_safety_repo_reaches_serving_stack():
    """The repo run must be clean AND have real coverage: jit roots in
    the engine reach the model decode path (an empty reachable set
    would make 'zero findings' vacuous)."""
    from tools.analyze.common import Corpus
    corpus = Corpus(REPO, ("src",))
    an = trace_safety._Analyzer(corpus)
    n_roots = trace_safety._seed_roots(an, corpus,
                                      trace_safety.ROOT_DIRS)
    findings = an.solve()
    assert findings == [], findings
    assert n_roots >= 5, n_roots
    reached = {fi.label for fi, _t in an.state.values()}
    assert "decode_step" in reached, reached
    assert "prefill" in reached, reached


# ---------------------------------------------------------------------------
# pass 2: shim enforcement
# ---------------------------------------------------------------------------

def test_shim_fixture_true_positive():
    found = shim.run(REPO, files=[os.path.join(FIX, "shim_bad.py")])
    assert _rules(found) == {"SHIM-IMPORT"}, found


def test_shim_clean_twin_silent():
    found = shim.run(REPO, files=[os.path.join(FIX, "shim_clean.py")])
    assert found == [], found


def test_shim_allows_the_shim_itself():
    ctx = os.path.join(REPO, "src", "repro", "distribution",
                       "context.py")
    assert shim.run(REPO, files=[ctx]) == []


# ---------------------------------------------------------------------------
# pass 3: recompile budget + cache-key hazards
# ---------------------------------------------------------------------------

def test_recompile_hazard_fixture_true_positives():
    found = recompile.run(REPO,
                          files=[os.path.join(FIX, "recompile_bad.py")])
    assert "JIT-CLOSURE" in _rules(found), found
    assert "JIT-STATIC-UNHASHABLE" in _rules(found), found


def test_recompile_hazard_clean_twin_silent():
    found = recompile.run(
        REPO, files=[os.path.join(FIX, "recompile_clean.py")])
    assert found == [], found


def test_recompile_budget_math():
    """budget_for/predict_prefill_shapes agree with the documented
    model: one program per bucket plus exact tail shapes."""
    buckets = (8, 16, 32, 64)
    shapes = recompile.predict_prefill_shapes(buckets, 2, range(1, 65))
    assert shapes == {(2, b) for b in buckets}
    assert len(shapes) <= recompile.budget_for(buckets, 64)
    # tail lengths beyond the largest bucket compile exact shapes
    shapes = recompile.predict_prefill_shapes((8, 16), 2, range(1, 33))
    assert (2, 20) in shapes
    assert len(shapes) <= recompile.budget_for((8, 16), 32)


def test_recompile_budget_detects_broken_bucketing(monkeypatch):
    """True positive for RECOMPILE-BUDGET: if the production bucketing
    regressed to exact shapes, the predicted signature count must blow
    the documented budget (this inequality is what run() asserts over
    the launch flag domains)."""
    from repro.serve.engine import Engine
    monkeypatch.setattr(Engine, "_bucket_len",
                        lambda self, L: int(L))   # bucketing disabled
    buckets = (8, 16, 32, 64)
    shapes = recompile.predict_prefill_shapes(buckets, 2, range(1, 65))
    assert len(shapes) > recompile.budget_for(buckets, 64)


# ---------------------------------------------------------------------------
# pass 4: concurrency lint
# ---------------------------------------------------------------------------

FIX_LOCK_SPECS = {
    "lock_bad.py": {
        "Peer": {
            "lock": "_lock",
            "protected": {"inbox"},
            "entry_points": {"push"},
        },
        "Worker": {
            "lock": "_lock",
            "protected": {"count"},
            "entry_points": {"increment", "forward"},
            "attr_classes": {"peer": ("lock_bad.py", "Peer")},
        },
    },
}
FIX_LOCK_ORDER = ["Peer._lock", "Worker._lock"]


def _clean_lock_specs():
    specs = {"lock_clean.py": {
        cls: dict(spec) for cls, spec in
        FIX_LOCK_SPECS["lock_bad.py"].items()}}
    specs["lock_clean.py"]["Worker"] = dict(
        specs["lock_clean.py"]["Worker"],
        attr_classes={"peer": ("lock_clean.py", "Peer")})
    return specs


def test_concurrency_fixture_true_positives():
    found = concurrency.run(FIX, specs=FIX_LOCK_SPECS,
                            lock_order=FIX_LOCK_ORDER)
    assert "LOCK-UNHELD" in _rules(found), found
    assert "LOCK-ORDER" in _rules(found), found
    unheld = [f for f in found if f.rule == "LOCK-UNHELD"]
    assert any("count" in f.message for f in unheld), unheld


def test_concurrency_clean_twin_silent():
    found = concurrency.run(FIX, specs=_clean_lock_specs(),
                            lock_order=FIX_LOCK_ORDER)
    assert found == [], found


def test_concurrency_repo_serving_layer_clean():
    assert concurrency.run(REPO) == []


# ---------------------------------------------------------------------------
# pass 5: packed-format invariants
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def packed_pair():
    from repro.core.deploy import pack_ffn, pack_weight
    rng = np.random.default_rng(0)
    w = rng.normal(size=(2, 32, 32)).astype(np.float32)
    w[:, :, 16:24] = 0.0                # prune some column blocks
    w[:, 8:16, :] = 0.0                 # and some row blocks
    pw = pack_weight(w, block_k=8, block_n=8)
    F, d = 32, 16
    w1 = rng.normal(size=(2, d, F)).astype(np.float32)
    w3 = rng.normal(size=(2, d, F)).astype(np.float32)
    w2 = rng.normal(size=(2, F, d)).astype(np.float32)
    w1[:, :, 8:16] = 0.0                # dead d_ff block
    w3[:, :, 8:16] = 0.0
    w2[:, 8:16, :] = 0.0
    pf = pack_ffn(w1, w3, w2, block_f=8, act="silu",
                  b2=np.zeros((2, d), np.float32))
    return pw, pf


def test_packed_clean_containers_validate(packed_pair):
    pw, pf = packed_pair
    assert packed.validate_packed_weight(pw) == []
    assert packed.validate_packed_ffn(pf) == []


def test_packed_weight_corruptions_caught(packed_pair):
    import copy
    pw, _ = packed_pair

    def corrupt(mutate):
        c = copy.deepcopy(pw)
        mutate(c)
        return {r for r, _ in packed.validate_packed_weight(c)}

    # PACK-DTYPE: kn table demoted to int64
    def to64(c):
        c.kn = np.asarray(c.kn, np.int64)
    assert "PACK-DTYPE" in corrupt(to64)

    # PACK-PAD: unsort the visit list
    def unsort(c):
        kn = np.array(c.kn)
        kn[0, :, [0, -1]] = kn[0, :, [-1, 0]]
        vals = np.array(c.vals)
        vals[0, [0, -1]] = vals[0, [-1, 0]]
        c.kn, c.vals = kn, vals
    assert "PACK-PAD" in corrupt(unsort)

    # PACK-PAD: a duplicate-coordinate padding visit gains values
    def dirty_pad(c):
        kn = np.array(c.kn)
        vals = np.array(c.vals)
        kn[0, 0, -1] = kn[0, 0, -2]
        kn[0, 1, -1] = kn[0, 1, -2]
        vals[0, -1] = 1.0
        c.kn, c.vals = kn, vals
    assert {"PACK-PAD", "PACK-CONSERVE"} & corrupt(dirty_pad)

    # PACK-KIND: declared block size contradicts the values
    def wrong_block(c):
        c.block = (4, 8)
    assert "PACK-KIND" in corrupt(wrong_block)

    # PACK-KIND: sharded container without a shard kind
    def no_kind(c):
        c.shards = 2
        c.shard_kind = None
    assert "PACK-KIND" in corrupt(no_kind)


def test_packed_ffn_corruptions_caught(packed_pair):
    import copy
    _, pf = packed_pair

    def corrupt(mutate):
        c = copy.deepcopy(pf)
        mutate(c)
        return {r for r, _ in packed.validate_packed_ffn(c)}

    # PACK-DTYPE: jv table missing entirely
    def no_jv(c):
        c.jv = None
    assert "PACK-DTYPE" in corrupt(no_jv)

    # PACK-PAD: live visit after the -1 padding suffix
    def pad_hole(c):
        jv = np.array(c.jv)
        jv[0, 0] = -1                   # -1 before live entries
        c.jv = jv
    assert "PACK-PAD" in corrupt(pad_hole)

    # PACK-PAD: jv not strictly increasing
    def dup_visit(c):
        jv = np.array(c.jv)
        jv[0, 1] = jv[0, 0]
        c.jv = jv
    assert "PACK-PAD" in corrupt(dup_visit)


def test_packed_repo_deployments_clean():
    """The real pass: pack + deploy the reduced model across shardings
    and check every container (and cross-sharding conservation)."""
    assert packed.run(REPO) == []


# ---------------------------------------------------------------------------
# pass 6: telemetry declaration discipline
# ---------------------------------------------------------------------------

def test_telemetry_fixture_true_positives():
    found = telemetry.run(
        FIX, files=[os.path.join(FIX, "telemetry_bad.py")])
    assert _rules(found) == {"TELEMETRY-DECLARED"}, found
    keys = {f.message.split("'")[1] for f in found}
    assert keys == {"bogus_counter", "mystery_gauge"}, found


def test_telemetry_clean_twin_silent():
    found = telemetry.run(
        FIX, files=[os.path.join(FIX, "telemetry_clean.py")])
    assert found == [], found


def test_telemetry_repo_serving_layer_clean():
    """Every stats[...] write in src/repro/serve/ is declared — and the
    scan has real coverage (the engine alone writes a dozen keys)."""
    assert telemetry.run(REPO) == []
    import ast as _ast
    eng = os.path.join(REPO, "src", "repro", "serve", "engine.py")
    with open(eng) as fh:
        tree = _ast.parse(fh.read())
    writes = [n for n in _ast.walk(tree)
              if isinstance(n, (_ast.Assign, _ast.AugAssign))
              and telemetry._stats_key(
                  n.target if isinstance(n, _ast.AugAssign)
                  else n.targets[0]) is not None]
    assert len(writes) >= 8, len(writes)


# ---------------------------------------------------------------------------
# driver: baseline + strict gate
# ---------------------------------------------------------------------------

def test_rule_registry_covers_all_findings():
    fix_findings = (
        trace_safety.run(FIX, subdirs=("",), root_dirs=("",))
        + shim.run(REPO, files=[os.path.join(FIX, "shim_bad.py")])
        + recompile.run(REPO,
                        files=[os.path.join(FIX, "recompile_bad.py")])
        + concurrency.run(FIX, specs=FIX_LOCK_SPECS,
                          lock_order=FIX_LOCK_ORDER)
        + telemetry.run(FIX,
                        files=[os.path.join(FIX, "telemetry_bad.py")]))
    for f in fix_findings:
        assert f.rule in RULES, f
        assert f.severity == "error"
        assert f.render()


def test_baseline_roundtrip(tmp_path):
    f1 = Finding("SHIM-IMPORT", "a.py", 3, "m1")
    f2 = Finding("LOCK-UNHELD", "b.py", 7, "m2")
    p = tmp_path / "baseline.json"
    write_baseline(str(p), [f1, f2])
    keys = set(load_baseline(str(p)))
    assert f1.key() in keys and f2.key() in keys
    # line numbers are not part of the key: moving a finding does not
    # invalidate its baseline entry
    assert Finding("SHIM-IMPORT", "a.py", 99, "m1").key() in keys


def test_repo_strict_is_clean():
    """The CI gate: the full suite over the repo has no findings beyond
    the (empty) baseline."""
    findings = run_all(passes=[p for p in PASS_NAMES
                               if p not in ("recompile", "packed")])
    baseline = set(load_baseline(
        os.path.join(REPO, "tools", "analyze", "baseline.json")))
    fresh = [f for f in findings if f.key() not in baseline]
    assert fresh == [], fresh
