"""SASP pruning invariants — unit + hypothesis property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import SASPConfig
from repro.core import pruning as P

RNG = np.random.default_rng(0)


def _params(shapes):
    return {f"ffn{i}": {"w1": {"w": jnp.asarray(
        RNG.normal(size=s).astype(np.float32))}}
        for i, s in enumerate(shapes)}


def test_tile_l1_matches_manual():
    w = jnp.asarray(RNG.normal(size=(8, 12)).astype(np.float32))
    t = P.tile_l1(w, 4, 4)
    assert t.shape == (2, 3)
    manual = np.abs(np.asarray(w)).reshape(2, 4, 3, 4).sum((1, 3))
    np.testing.assert_allclose(np.asarray(t), manual, rtol=1e-6)


def test_apply_block_mask_equals_upsample():
    w = jnp.asarray(RNG.normal(size=(16, 24)).astype(np.float32))
    mask = jnp.asarray(RNG.random((4, 3)) > 0.5)
    a = P.apply_block_mask(w, mask)
    b = w * P.upsample_mask(mask, 4, 8).astype(w.dtype)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


@settings(max_examples=30, deadline=None)
@given(sparsity=st.floats(0.0, 0.95),
       kb=st.integers(2, 6), nb=st.integers(2, 6),
       nmats=st.integers(1, 4))
def test_global_budget_exact(sparsity, kb, nb, nmats):
    """Exactly floor(sparsity × total_tiles) tiles pruned model-wide."""
    bk = bn = 4
    params = _params([(kb * bk, nb * bn)] * nmats)
    sasp = SASPConfig(enabled=True, block_k=bk, block_n=bn,
                      sparsity=sparsity)
    masks = P.compute_sasp_masks(params, sasp,
                                 is_prunable=lambda p: True)
    total = sum(m.size for m in masks.values())
    pruned = sum(int((~m).sum()) for m in masks.values())
    assert total == kb * nb * nmats
    assert pruned == int(np.floor(sparsity * total))


def test_lowest_l1_tiles_pruned_first():
    bk = bn = 4
    w = np.ones((8, 8), np.float32)
    w[:4, :4] = 0.001                  # tile (0,0) has lowest L1
    params = {"ffn": {"w1": {"w": jnp.asarray(w)}}}
    sasp = SASPConfig(enabled=True, block_k=bk, block_n=bn, sparsity=0.25)
    masks = P.compute_sasp_masks(params, sasp, is_prunable=lambda p: True)
    m = np.asarray(list(masks.values())[0])
    assert not m[0, 0] and m.sum() == 3


def test_heterogeneous_per_layer_rates():
    """Global selection prunes low-magnitude layers harder (paper Fig 8)."""
    bk = bn = 4
    small = RNG.normal(size=(16, 16)).astype(np.float32) * 0.01
    large = RNG.normal(size=(16, 16)).astype(np.float32) * 1.0
    params = {"a": {"w1": {"w": jnp.asarray(small)}},
              "b": {"w1": {"w": jnp.asarray(large)}}}
    sasp = SASPConfig(enabled=True, block_k=bk, block_n=bn, sparsity=0.5)
    masks = P.compute_sasp_masks(params, sasp, is_prunable=lambda p: True)
    per = P.per_matrix_sparsity(masks)
    a = [v for k, v in per.items() if k.startswith("a")][0]
    b = [v for k, v in per.items() if k.startswith("b")][0]
    assert a > 0.9 and b < 0.1


def test_prune_params_zeroes_exactly_masked_tiles():
    params = _params([(16, 16)])
    sasp = SASPConfig(enabled=True, block_k=4, block_n=4, sparsity=0.4)
    pruned, masks = P.prune_params(params, sasp,
                                   is_prunable=lambda p: True)
    (path, mask), = masks.items()
    w0 = np.asarray(params["ffn0"]["w1"]["w"])
    w1 = np.asarray(pruned["ffn0"]["w1"]["w"])
    m = np.asarray(mask)
    up = np.repeat(np.repeat(m, 4, 0), 4, 1)
    np.testing.assert_allclose(w1, w0 * up)


def test_scope_ffn_excludes_attention():
    sasp = SASPConfig(enabled=True, scope="ffn")
    pred = P.scope_predicate(sasp)

    class K:
        def __init__(self, key):
            self.key = key

    assert pred((K("segments"), K("0"), K("slot0"), K("ffn"), K("w1"),
                 K("w")))
    assert not pred((K("segments"), K("0"), K("slot0"), K("mixer"),
                     K("wq"), K("w")))


def test_effective_blocks_clamped_to_small_experts():
    # 512-wide expert with 512-block => whole-matrix granularity
    assert P.effective_blocks((512, 128), 512, 512) == (512, 128)


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 2000))
def test_cubic_schedule_monotone_bounded(step):
    s = P.cubic_sparsity_schedule(step, start_step=100, end_step=1000,
                                  final_sparsity=0.4)
    s2 = P.cubic_sparsity_schedule(step + 1, start_step=100,
                                   end_step=1000, final_sparsity=0.4)
    assert 0.0 <= s <= 0.4 and s2 >= s - 1e-12


def test_moe_expert_stack_masks():
    """Leading expert dims flow through scoring + masking."""
    w = jnp.asarray(RNG.normal(size=(4, 16, 16)).astype(np.float32))
    params = {"moe": {"w1": {"w": w}}}
    sasp = SASPConfig(enabled=True, block_k=4, block_n=4, sparsity=0.5)
    masks = P.compute_sasp_masks(params, sasp, is_prunable=lambda p: True)
    (_, mask), = masks.items()
    assert mask.shape == (4, 4, 4)
    pruned, _ = P.prune_params(params, sasp, is_prunable=lambda p: True)
    assert pruned["moe"]["w1"]["w"].shape == (4, 16, 16)
