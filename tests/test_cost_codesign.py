"""Cost-model fidelity vs paper Table 3 + codesign explorer invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.codesign import (
    best_under_qos,
    exponential_qos_proxy,
    pareto_front,
    speedup_at_fixed_qos,
    sweep,
)
from repro.core.cost_model import (
    GEMMWork,
    SystolicConfig,
    encoder_gemms,
    energy_j,
    gemm_cycles,
    speedup_vs_cpu,
)

PAPER_NOSASP = {("fp32", 4): 8.42, ("fp32", 8): 19.79,
                ("fp32", 16): 35.22, ("fp32", 32): 50.95,
                ("int8", 4): 8.03, ("int8", 8): 20.18,
                ("int8", 16): 36.53, ("int8", 32): 61.33}

GEMMS = encoder_gemms(num_layers=18, d_model=512, d_ff=2048, seq=512)


@pytest.mark.parametrize("quant,size", list(PAPER_NOSASP))
def test_fit_within_5pct_of_paper_table3(quant, size):
    sp = speedup_vs_cpu(SystolicConfig(size, quant), GEMMS)
    assert abs(sp / PAPER_NOSASP[(quant, size)] - 1) < 0.05


def test_area_matches_paper():
    assert abs(SystolicConfig(32, "fp32").area_mm2 - 3.34) < 0.1
    assert abs(SystolicConfig(8, "fp32").area_mm2 - 0.21) < 0.02


@settings(max_examples=25, deadline=None)
@given(s1=st.floats(0.0, 0.4), s2=st.floats(0.4, 0.8),
       size=st.sampled_from([4, 8, 16, 32]))
def test_speedup_monotone_in_sparsity(s1, s2, size):
    sa = SystolicConfig(size, "int8")
    g1 = encoder_gemms(num_layers=4, d_model=256, d_ff=1024, seq=128,
                       ffn_sparsity=s1)
    g2 = encoder_gemms(num_layers=4, d_model=256, d_ff=1024, seq=128,
                       ffn_sparsity=s2)
    assert speedup_vs_cpu(sa, g2) >= speedup_vs_cpu(sa, g1)


def test_int8_reduces_energy_and_weight_load_time():
    g = GEMMS
    for size in (8, 16, 32):
        e_f = energy_j(SystolicConfig(size, "fp32"), g)
        e_i = energy_j(SystolicConfig(size, "int8"), g)
        assert e_i < e_f
    # weight programming cycles drop 4x with int8 bus packing
    w = GEMMWork(1, 512, 512)      # M=1 isolates programming cost
    c_f = gemm_cycles(SystolicConfig(32, "fp32"), w)
    c_i = gemm_cycles(SystolicConfig(32, "int8"), w)
    assert c_i < c_f


def test_sublinear_speedup_at_fixed_qos():
    pts = sweep(lambda s: encoder_gemms(num_layers=18, d_model=512,
                                        d_ff=2048, seq=512,
                                        ffn_sparsity=s),
                exponential_qos_proxy())
    sel = speedup_at_fixed_qos(pts, 5.0, "int8")
    sizes = sorted(sel)
    assert len(sizes) >= 3
    # PE count grows 64x from 4->32; speedup must grow much less
    assert sel[sizes[-1]] / sel[sizes[0]] < (sizes[-1] / sizes[0]) ** 2 / 3


def test_best_under_qos_respects_target():
    pts = sweep(lambda s: encoder_gemms(num_layers=4, d_model=256,
                                        d_ff=1024, seq=128,
                                        ffn_sparsity=s),
                exponential_qos_proxy())
    sel = best_under_qos(pts, 5.0)
    assert sel and all(p.qos <= 5.0 for p in sel.values())


def test_pareto_front_is_nondominated():
    pts = sweep(lambda s: encoder_gemms(num_layers=4, d_model=256,
                                        d_ff=1024, seq=128,
                                        ffn_sparsity=s),
                exponential_qos_proxy(), tiles=(4, 8))
    front = pareto_front(pts)
    assert 0 < len(front) < len(pts)
    for p in front:
        for o in pts:
            dominates = (o.qos <= p.qos and o.time_s <= p.time_s
                         and o.area_energy <= p.area_energy
                         and (o.qos < p.qos or o.time_s < p.time_s
                              or o.area_energy < p.area_energy))
            assert not dominates
