"""Per-arch smoke tests (reduced configs) + decode consistency.

Every assigned architecture instantiates a REDUCED config of the same
family and runs one forward + one train step on CPU, asserting output
shapes and the absence of NaNs. The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import lm
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.train_step import make_train_step

KEY = jax.random.PRNGKey(0)


def _reduced(arch):
    return reduced(get_config(arch), layers=4, d_model=64, vocab=128)


def _batch(cfg, B=2, S=32):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    b = {"tokens": toks}
    if cfg.frontend != "none":
        b["embeds"] = jax.random.normal(jax.random.PRNGKey(2),
                                        (B, S, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = _reduced(arch)
    params = lm.init_params(KEY, cfg)
    b = _batch(cfg)
    logits = lm.forward(params, cfg, b["tokens"],
                        embeds=b.get("embeds"))
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    cfg = _reduced(arch)
    params = lm.init_params(KEY, cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    step = make_train_step(cfg, opt_cfg)
    b = _batch(cfg)
    p2, o2, metrics = step(params, opt, b)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))), jax.tree.map(
            lambda a, b2: (a.astype(jnp.float32)
                           - b2.astype(jnp.float32)), params, p2), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen3-32b", "gemma3-4b", "mamba2-780m",
                                  pytest.param("jamba-1.5-large-398b",
                                               marks=pytest.mark.slow),
                                  "granite-moe-1b-a400m"])
def test_decode_matches_forward(arch):
    cfg = _reduced(arch)
    params = lm.init_params(KEY, cfg)
    B, S, S0 = 2, 24, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full = lm.forward(params, cfg, toks)
    logits, caches = lm.prefill(params, cfg, toks[:, :S0], cache_len=S)
    errs = [float(jnp.max(jnp.abs(logits[:, 0] - full[:, S0 - 1])))]
    for t in range(S0, S):
        pos = jnp.full((B,), t, jnp.int32)
        logits, caches = lm.decode_step(params, cfg, toks[:, t:t + 1],
                                        pos, caches)
        errs.append(float(jnp.max(jnp.abs(logits[:, 0] - full[:, t]))))
    # fp32 tolerance: chunked-SSD prefill vs per-token recurrence differ
    # by reassociated exp/cumsum ordering; MoE capacity drops are
    # context-length-dependent (prefill routes 16 tokens, the full
    # forward routes 24 — different overflow sets), so MoE archs get a
    # wider bound.
    bound = 2.5e-2 if cfg.moe is not None else 5e-3
    assert max(errs) < bound, errs


def test_segment_plan_covers_exact_layer_count():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        plan = lm.segment_plan(cfg)
        total = sum(len(pattern) * repeat for pattern, repeat in plan)
        assert total == cfg.num_layers, (arch, plan)


def test_jamba_plan_structure():
    cfg = get_config("jamba-1.5-large-398b")
    plan = lm.segment_plan(cfg)
    assert len(plan) == 1
    pattern, repeat = plan[0]
    assert repeat == 9 and len(pattern) == 8
    from repro.configs import MIXER_ATTN
    attn_slots = [i for i, s in enumerate(pattern) if s[0] == MIXER_ATTN]
    assert attn_slots == [4]          # 1 attn per 8, offset 4
    moe_slots = [i for i, s in enumerate(pattern) if s[2] == 1]
    assert moe_slots == [1, 3, 5, 7]  # alternating MoE


def test_gemma_plan_structure():
    cfg = get_config("gemma3-4b")
    plan = lm.segment_plan(cfg)
    total = sum(len(p) * r for p, r in plan)
    assert total == 34
    from repro.configs import ATTN_GLOBAL, ATTN_LOCAL
    pattern, repeat = plan[0]
    assert repeat == 5 and len(pattern) == 6
    assert [s[1] for s in pattern] == [ATTN_LOCAL] * 5 + [ATTN_GLOBAL]
    # remainder: 4 local layers
    assert plan[1][1] * len(plan[1][0]) == 4


def test_microbatched_step_matches_full_batch():
    cfg = _reduced("qwen3-32b")
    params = lm.init_params(KEY, cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    b = _batch(cfg, B=4, S=32)
    s1 = make_train_step(cfg, opt_cfg)
    s2 = make_train_step(cfg, opt_cfg, n_microbatches=2)
    p1, _, m1 = s1(params, adamw_init(params, opt_cfg), b)
    p2, _, m2 = s2(params, adamw_init(params, opt_cfg), b)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - c.astype(jnp.float32))))
               for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert diff < 1e-4
