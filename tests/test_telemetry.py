"""Serving telemetry subsystem (DESIGN.md §18): mergeable histogram
snapshots (associative + commutative, quantiles invariant to merge
order), the bounded span-tracer ring, Chrome trace-event schema
round-trips, the backward-compatible CounterView surface, Prometheus
export, per-path tok/s gauges + spec acceptance EMA — and the standing
acceptance bar: greedy streams are bit-identical with tracing armed,
across the plain, paged, shared-prefix, speculative, and
preempt/spill/fault paths."""
import dataclasses
import json

import numpy as np
import jax
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # the fixed twin below still runs
    HAVE_HYPOTHESIS = False

from repro.configs import get_config, reduced
from repro.models import lm
from repro.serve.chaos import ChaosConfig, ChaosMonkey
from repro.serve.engine import Engine, Request
from repro.serve.frontend import ClusterFrontend, FrontendConfig, \
    make_local_hosts
from repro.serve.scheduler import SchedulerConfig, ShardedScheduler
from repro.serve.telemetry import CounterView, DECLARED_STATS, \
    Histogram, HistSnapshot, MetricsRegistry, SpanTracer, Telemetry, \
    TTFT_BOUNDS_S, merged_ttft_stats, nearest_rank, pcts_ms

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# nearest-rank quantiles (the deduped bench/CLI helpers)
# ---------------------------------------------------------------------------


def _legacy_pcts_ms(lats):
    """The formula that used to live (twice) in bench_engine.py and
    launch/serve.py — dedup must not move any reported number."""
    xs = sorted(lats)
    pct = lambda q: xs[min(len(xs) - 1, int(len(xs) * q))] * 1e3
    return pct(0.5), pct(0.95)


def test_pcts_ms_matches_legacy_formula():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 7, 19, 20, 100):
        lats = sorted(rng.exponential(0.1, size=n).tolist())
        assert pcts_ms(lats) == _legacy_pcts_ms(lats)
    assert nearest_rank([5.0], 0.95) == 5.0
    with pytest.raises(ValueError):
        nearest_rank([], 0.5)


# ---------------------------------------------------------------------------
# histogram snapshots: merge is associative + commutative
# ---------------------------------------------------------------------------


def _snap(vals, bounds=TTFT_BOUNDS_S):
    h = Histogram(bounds)
    for v in vals:
        h.observe(v)
    return h.snapshot()


def _same(x: HistSnapshot, y: HistSnapshot) -> None:
    # everything discrete is exactly equal; total is a float sum, so
    # merge order can move its last bit
    assert (x.bounds, x.counts, x.count, x.vmin, x.vmax) == \
        (y.bounds, y.counts, y.count, y.vmin, y.vmax)
    assert x.total == pytest.approx(y.total)


def _assert_merge_laws(a_vals, b_vals, c_vals):
    a, b, c = _snap(a_vals), _snap(b_vals), _snap(c_vals)
    _same(a.merge(b), b.merge(a))                        # commutative
    _same(a.merge(b).merge(c), a.merge(b.merge(c)))      # associative
    # any merge order equals one histogram observing the union
    union = _snap(list(a_vals) + list(b_vals) + list(c_vals))
    _same(c.merge(a).merge(b), union)
    for q in (0.5, 0.95, 0.99):
        assert a.merge(b).merge(c).quantile(q) == union.quantile(q)


def test_hist_merge_laws_fixed_twin():
    rng = np.random.default_rng(1)
    for _ in range(25):
        groups = [rng.exponential(0.2,
                                  size=int(rng.integers(0, 40))).tolist()
                  for _ in range(3)]
        _assert_merge_laws(*groups)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(*(st.lists(st.floats(min_value=0.0, max_value=100.0,
                                allow_nan=False), max_size=30)
             for _ in range(3)))
    def test_hist_merge_laws_property(a_vals, b_vals, c_vals):
        _assert_merge_laws(a_vals, b_vals, c_vals)


def test_hist_quantile_semantics():
    bounds = (1.0, 2.0, 4.0)
    assert HistSnapshot.empty(bounds).quantile(0.5) is None
    # quantile resolves to the upper bound of the holding bucket
    assert _snap([0.5], bounds).quantile(0.5) == 1.0
    assert _snap([1.5, 1.6, 1.7], bounds).quantile(0.5) == 2.0
    # overflow bucket answers vmax, the only exact value it has
    assert _snap([9.0, 11.0], bounds).quantile(0.95) == 11.0
    with pytest.raises(ValueError, match="different bucket bounds"):
        _snap([1.0], bounds).merge(_snap([1.0], (1.0, 2.0)))
    with pytest.raises(ValueError, match="strictly increasing"):
        Histogram((2.0, 1.0))
    d = _snap([0.5, 3.0], bounds).as_dict()
    assert d["count"] == 2 and d["min"] == 0.5 and d["max"] == 3.0


def test_merged_ttft_stats_order_independent():
    t1, t2 = Telemetry(), Telemetry()
    for v in (0.002, 0.003, 0.004):
        t1.observe_ttft("interactive", v)
    for v in (0.2, 0.4):
        t2.observe_ttft("interactive", v)
    t2.observe_ttft("batch", 1.3)
    ab = merged_ttft_stats([t1, t2])
    assert ab == merged_ttft_stats([t2, t1])
    assert ab["interactive"]["count"] == 5
    assert ab["batch"]["count"] == 1
    assert ab["interactive"]["p50_ms"] <= ab["interactive"]["p95_ms"]
    # the facade view is the single-instance merge
    assert t1.ttft_stats()["interactive"]["count"] == 3


# ---------------------------------------------------------------------------
# span tracer: bounded ring, free when disabled, Chrome schema
# ---------------------------------------------------------------------------


def test_tracer_ring_never_exceeds_capacity():
    tr = SpanTracer(capacity=16, enabled=True)
    for i in range(50):
        tr.instant(f"ev{i}", tid=0)
    assert len(tr) == 16
    assert tr.dropped == 50 - 16
    names = [e["name"] for e in tr.events()]
    assert names == [f"ev{i}" for i in range(34, 50)]   # oldest fell off


def test_tracer_disabled_is_inert():
    tr = SpanTracer(capacity=8, enabled=False)
    assert tr.t0() == 0.0                # no clock read when disabled
    tr.instant("x")
    tr.complete("y", 0.0)
    assert len(tr) == 0 and tr.events() == []


def _check_chrome(trace):
    """Schema check for the Chrome trace-event JSON object format —
    the invariants Perfetto / chrome://tracing need to load a file."""
    assert set(trace) == {"traceEvents", "displayTimeUnit"}
    assert trace["displayTimeUnit"] == "ms"
    for ev in trace["traceEvents"]:
        assert isinstance(ev["name"], str) and ev["name"]
        assert isinstance(ev["cat"], str)
        assert isinstance(ev["ts"], (int, float))       # microseconds
        assert isinstance(ev["pid"], int)
        assert isinstance(ev["tid"], int)
        assert isinstance(ev["args"], dict)
        if ev["ph"] == "X":                             # complete span
            assert ev["dur"] >= 0.0
        else:
            assert ev["ph"] == "i" and ev["s"] == "g"   # global instant
    return [e["name"] for e in trace["traceEvents"]]


def test_chrome_trace_schema_roundtrip(tmp_path):
    tr = SpanTracer(capacity=64, enabled=True)
    tr.instant("submit", tid=1, rid=7)
    t0 = tr.t0()
    tr.complete("prefill", t0, tid=1, tokens=12)
    tr.instant("admit", tid=0, cat="sched")
    path = tmp_path / "trace.json"
    assert tr.write(str(path)) == 3
    with open(path) as fh:
        trace = json.load(fh)
    names = _check_chrome(trace)
    assert names == ["submit", "prefill", "admit"]
    spans = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert spans[0]["args"] == {"tokens": 12}


# ---------------------------------------------------------------------------
# counters + registry export
# ---------------------------------------------------------------------------


def test_counter_view_backward_compatible_surface():
    reg = MetricsRegistry()
    view = reg.counter_scope(rank=0).declare(["admitted", "failed"])
    view["admitted"] += 2
    view.update(failed=1)
    view["memory"] = {"pages": 4}        # non-int side object
    assert dict(view, extra=9)["extra"] == 9
    assert view["memory"] == {"pages": 4}
    assert ("memory", 4) not in view.int_items()
    # declare-if-absent: a revived rank re-declaring must not zero
    again = reg.counter_scope(rank=0).declare(["admitted", "failed"])
    assert again is view and again["admitted"] == 2
    # distinct label sets are distinct scopes
    assert reg.counter_scope(rank=1)["admitted"] == 0 \
        if "admitted" in reg.counter_scope(rank=1) else True


def test_registry_prometheus_export():
    reg = MetricsRegistry()
    view = reg.counter_scope(rank=0).declare(["admitted"])
    view["admitted"] += 3
    reg.gauge("serve_queue_depth", 5)
    reg.gauge("serve_none_gauge", lambda: None)          # skipped
    reg.histogram("serve_ttft_seconds", (0.1, 1.0),
                  slo="interactive").observe(0.05)
    reg.histogram("serve_ttft_seconds", (0.1, 1.0),
                  slo="interactive").observe(0.5)
    reg.register_collector(lambda: {"serve_custom_total": 7}, key="c")
    reg.register_collector(lambda: {"serve_custom_total": 8}, key="c")
    text = reg.prometheus()
    assert 'serve_admitted_total{rank="0"} 3' in text
    assert "# TYPE serve_admitted_total counter" in text
    assert "serve_queue_depth 5" in text
    assert "serve_none_gauge" not in text
    assert 'le="0.1"' in text and 'le="+Inf"' in text
    assert 'serve_ttft_seconds_count{slo="interactive"} 2' in text
    # keyed collector registration is idempotent — the replacement wins
    assert "serve_custom_total 8" in text
    assert "serve_custom_total 7" not in text


def test_path_gauges_and_accept_ema():
    tel = Telemetry()
    assert tel.tok_s("packed") == 0.0
    tel.note_tokens("packed", 40)
    assert tel.tok_s("packed") > 0.0
    text = tel.prometheus()
    assert 'serve_path_tok_s{path="packed"}' in text
    assert "serve_spec_accept_ema" not in text   # None until first round
    tel.note_spec_round(3, 4)
    assert tel.accept_ema.value == pytest.approx(0.75)
    tel.note_spec_round(0, 0)                    # no division by zero
    assert "serve_spec_accept_ema 0.75" in tel.prometheus()
    assert "admitted" in DECLARED_STATS          # contract sanity


# ---------------------------------------------------------------------------
# bit-identity: tracing armed must not move a single token
# ---------------------------------------------------------------------------


def _setup():
    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=64, vocab=64)
    params = lm.init_params(KEY, cfg)
    params = jax.tree.map(lambda a: a * 3.0, params)  # see test_scheduler
    return cfg, params


def _mk_requests(n, rng, max_new=6):
    return [Request(rid=i,
                    prompt=rng.integers(0, 64, size=(int(
                        rng.integers(4, 30)),)).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


@pytest.mark.parametrize("kw", [
    {},                                                        # plain
    dict(kv_pages=16, kv_page_len=8),                          # paged
    dict(kv_pages=16, kv_page_len=8, kv_share=True),           # share
    dict(kv_pages=16, kv_page_len=8, draft_sparsity=0.75,      # spec
         draft_k=4),
], ids=["plain", "paged", "share", "spec"])
def test_engine_streams_bit_identical_with_tracing(kw):
    cfg, params = _setup()

    def drive(trace):
        rng = np.random.default_rng(0)
        eng = Engine(params, cfg, batch_slots=2, cache_len=64,
                     telemetry=Telemetry(trace=trace), **kw)
        done = eng.run(_mk_requests(5, rng))
        return {r.rid: r.out_tokens for r in done}, eng

    ref, _ = drive(False)
    got, eng = drive(True)
    assert got == ref
    names = set(_check_chrome(eng.telemetry.tracer.chrome()))
    assert {"submit", "admit", "prefill", "token"} <= names, names
    if "draft_sparsity" in kw:
        assert "spec_round" in names, names


def test_preempt_spill_resume_traced_and_bit_identical(tmp_path):
    """The forced preempt→spill→fault cycle from test_memory.py, with
    the tracer armed: streams still equal the (untraced) solo engine,
    and the written trace file carries the full lifecycle."""
    cfg, params = _setup()
    rng = np.random.default_rng(4)
    batch = Request(rid=0, prompt=rng.integers(0, 64, size=(18,))
                    .astype(np.int32), max_new_tokens=14, slo="batch")
    inter = Request(rid=1, prompt=rng.integers(0, 64, size=(40,))
                    .astype(np.int32), max_new_tokens=3,
                    slo="interactive", deadline=0.01)
    ref = {}
    for r in (batch, inter):
        solo = Request(rid=r.rid, prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens)
        ref[r.rid] = Engine(params, cfg, batch_slots=1,
                            cache_len=64).run([solo])[0].out_tokens
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              policy="edf", preempt=True,
                              preempt_mode="kv", kv_pages=8,
                              kv_page_len=8, kv_host_pages=8),
        telemetry=Telemetry(trace=True))
    assert sched.submit(batch)
    for _ in range(4):
        sched.step()
    assert sched.submit(inter)
    done = []
    while sched.has_work():
        done.extend(sched.step())
    assert {r.rid: r.out_tokens for r in done} == ref
    assert sched.stats()["preemptions"] >= 1
    path = tmp_path / "sched_trace.json"
    sched.telemetry.write_trace(str(path))
    with open(path) as fh:
        names = set(_check_chrome(json.load(fh)))
    assert {"submit", "admit", "prefill", "token", "preempt",
            "spill", "resume"} <= names, names
    # TTFT histogram observed both SLO classes through the same run
    ttft = sched.stats()["ttft"]
    assert ttft["interactive"]["count"] >= 1
    assert ttft["batch"]["count"] >= 1


@pytest.mark.chaos
def test_chaos_kill_trace_loads_and_carries_recovery(tmp_path):
    """The acceptance trace: a ``kill:0@3`` chaos run (then a revive)
    exports one Perfetto-loadable file whose events span both hosts'
    rank activity (host pids) and the frontend's own retry/death/revive
    instants (pid -1) — and the streams still finish bit-identically."""
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    reqs = _mk_requests(6, rng, max_new=4)
    solo = {}
    for r in reqs:
        s = Request(rid=r.rid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens)
        solo[r.rid] = Engine(params, cfg, batch_slots=1,
                             cache_len=64).run([s])[0].out_tokens
    chaos = ChaosMonkey(ChaosConfig(kill_at_step={0: 3}))
    hosts = make_local_hosts(
        params, cfg, hosts=2,
        sched=SchedulerConfig(slots_per_rank=2, cache_len=64),
        chaos=chaos, trace=True)
    fe = ClusterFrontend(hosts, FrontendConfig(retries=2,
                                               backoff_base=0.001,
                                               rng_seed=1))
    completed = fe.run(reqs)
    assert {r.rid: r.out_tokens for r in completed} == solo
    assert fe.n_retries >= 1
    fe.revive_host(0)

    path = tmp_path / "chaos_trace.json"
    n = fe.write_trace(str(path))
    with open(path) as fh:
        trace = json.load(fh)
    assert len(trace["traceEvents"]) == n
    names = set(_check_chrome(trace))
    # (a host-level kill leaves its ranks intact, so host_revive — not
    # the scheduler's revive_rank — is the recovery marker here)
    assert {"submit", "admit", "prefill", "token", "host_kill",
            "host_dead", "retry", "host_revive"} <= names, names
    pids = {e["pid"] for e in trace["traceEvents"]}
    assert {-1, 0, 1} <= pids, pids      # frontend + both hosts
    # events are globally time-ordered (the exporter sorts the concat)
    ts = [e["ts"] for e in trace["traceEvents"]]
    assert ts == sorted(ts)
    # cluster-level Prometheus: per-host counter series, no duplicates
    text = fe.prometheus()
    assert 'host="0"' in text and 'host="1"' in text
    assert "serve_frontend_retries_total" in text
    # merged TTFT view aggregates across hosts
    ttft = fe.stats()["ttft"]
    assert sum(d["count"] for d in ttft.values()) >= len(reqs)


def test_exec_path_labels_feed_gauges():
    from repro.configs import SASPConfig
    from repro.serve.engine import _exec_path_label
    cfg, params = _setup()
    assert _exec_path_label(params, cfg) == "dense"
    sasp = SASPConfig(enabled=True, block_k=8, block_n=8, sparsity=0.25)
    assert _exec_path_label(
        params, dataclasses.replace(cfg, sasp=sasp)) == sasp.path
    assert _exec_path_label(
        params, dataclasses.replace(
            cfg, sasp=dataclasses.replace(sasp, quantize=True))) == "int8"
    # decode tokens are credited to the engine's resolved label
    eng = Engine(params, cfg, batch_slots=1, cache_len=64)
    assert eng.path_label == "dense"
    rng = np.random.default_rng(2)
    eng.run(_mk_requests(1, rng, max_new=4))
    assert eng.telemetry.tok_s("dense") > 0.0
