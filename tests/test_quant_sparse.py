"""Quantization + block-sparse container tests (unit + property)."""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import quantization as Q
from repro.core.sparse import (
    BlockSparseWeight,
    bsr_from_mask,
    bsr_matmul,
    bsr_to_dense,
    flat_block_list,
    stack_bsr,
)

RNG = np.random.default_rng(1)


@settings(max_examples=25, deadline=None)
@given(kb=st.integers(1, 4), nb=st.integers(1, 4),
       scale=st.floats(1e-3, 1e3))
def test_int8_roundtrip_error_bound(kb, nb, scale):
    w = jnp.asarray(RNG.normal(size=(kb * 8, nb * 8)).astype(np.float32)
                    * scale)
    qw = Q.quantize_int8(w, 8, 8)
    wd = Q.dequantize_int8(qw)
    # per-block max error <= scale/2 = amax/254
    err = np.abs(np.asarray(w) - np.asarray(wd))
    amax = np.abs(np.asarray(w)).reshape(kb, 8, nb, 8).max((1, 3))
    bound = np.repeat(np.repeat(amax, 8, 0), 8, 1) / 127.0 * 0.5 + 1e-7
    assert (err <= bound + 1e-6 * amax.max()).all()


def test_int8_rel_error_typical():
    w = jnp.asarray(RNG.normal(size=(64, 64)).astype(np.float32))
    assert Q.quant_error(w, 16, 16) < 0.01


def test_pack_unpack_exact():
    q = jnp.asarray(RNG.integers(-127, 128, size=(4, 16)), jnp.int8)
    p = Q.pack_int8_to_u32(q)
    assert p.shape == (4, 4)
    np.testing.assert_array_equal(np.asarray(Q.unpack_u32_to_int8(p)),
                                  np.asarray(q))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 700))
def test_1d_block_quant_roundtrip(n):
    x = jnp.asarray(RNG.normal(size=(n,)).astype(np.float32))
    q, s = Q.quantize_1d_blocks(x)
    y = Q.dequantize_1d_blocks(q, s, (n,))
    amax = float(jnp.max(jnp.abs(x))) + 1e-9
    assert float(jnp.max(jnp.abs(x - y))) <= amax / 127.0 + 1e-7


# ---------------------------------------------------------------------------


def _mask(KB, NB, density=0.5, ensure_nonempty=False):
    m = RNG.random((KB, NB)) < density
    if ensure_nonempty and not m.any():
        m[0, 0] = True
    return m


@pytest.mark.parametrize("K,N,bk,bn,density", [
    (32, 32, 8, 8, 0.5), (64, 128, 16, 32, 0.2), (48, 48, 16, 16, 1.0),
    (32, 32, 8, 8, 0.02),
])
def test_bsr_roundtrip_and_matmul(K, N, bk, bn, density):
    w = RNG.normal(size=(K, N)).astype(np.float32)
    mask = _mask(K // bk, N // bn, density, ensure_nonempty=True)
    bsr = bsr_from_mask(w, mask, bk, bn)
    dense = np.asarray(bsr_to_dense(bsr))
    expect = w * np.repeat(np.repeat(mask, bk, 0), bn, 1)
    np.testing.assert_allclose(dense, expect, rtol=1e-6)
    x = jnp.asarray(RNG.normal(size=(8, K)).astype(np.float32))
    y = bsr_matmul(x, bsr)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ expect,
                               rtol=1e-4, atol=1e-4)


def test_bsr_quantized_matmul_close():
    K, N, bk, bn = 64, 64, 16, 16
    w = RNG.normal(size=(K, N)).astype(np.float32)
    mask = _mask(4, 4, 0.6, True)
    bsr = bsr_from_mask(w, mask, bk, bn, quantize=True)
    x = jnp.asarray(RNG.normal(size=(8, K)).astype(np.float32))
    y = np.asarray(bsr_matmul(x, bsr))
    expect = np.asarray(x) @ (w * np.repeat(np.repeat(mask, bk, 0), bn, 1))
    denom = np.abs(expect).max() + 1e-9
    assert np.abs(y - expect).max() / denom < 2e-2


def test_stack_bsr_scan_layout():
    K, N, bk, bn = 32, 32, 8, 8
    masks = [_mask(4, 4, 0.5, True) for _ in range(3)]
    k_max = max(int(m.sum(0).max()) for m in masks)
    bsrs = [bsr_from_mask(RNG.normal(size=(K, N)).astype(np.float32),
                          m, bk, bn, k_max=k_max) for m in masks]
    stacked = stack_bsr(bsrs)
    assert stacked.vals.shape == (3, k_max, 4, 8, 8)
    for i in range(3):
        np.testing.assert_allclose(np.asarray(stacked.vals[i]),
                                   np.asarray(bsrs[i].vals))


def test_flat_block_list_sorted_by_column():
    mask = _mask(5, 4, 0.5, True)
    kn = flat_block_list(mask)
    ns = kn[:, 1]
    assert (np.diff(ns) >= 0).all()
    assert len(kn) == int(mask.sum())


def test_bsr_pytree_static_aux():
    import jax
    w = RNG.normal(size=(16, 16)).astype(np.float32)
    bsr = bsr_from_mask(w, _mask(2, 2, 1.0), 8, 8)
    leaves = jax.tree_util.tree_leaves(bsr)
    # only arrays are leaves; shape/block are static aux
    assert all(hasattr(l, "shape") for l in leaves)
    rebuilt = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(bsr), leaves)
    assert rebuilt.block == (8, 8) and rebuilt.shape == (16, 16)
