"""Sharded request scheduler (DESIGN.md §11): continuous-batching
bit-identity (a slot freed by EOS is refilled from the queue and every
stream matches the solo single-batch engine), per-rank queue sharding,
admission control, SJF vs FCFS ordering, and the drain-batch baseline.
The 1×2-mesh packed variant of the bit-identity contract lives in
tests/test_distribution.py (``sched_mesh`` worker)."""
import dataclasses

import numpy as np
import jax
import pytest

from repro.configs import SASPConfig, get_config, reduced
from repro.core.deploy import deploy_packed
from repro.core.pruning import prune_params
from repro.models import lm
from repro.serve.engine import Engine, Request
from repro.serve.scheduler import SchedulerConfig, ShardedScheduler

KEY = jax.random.PRNGKey(0)


def _setup(packed=False):
    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=64, vocab=64)
    params = lm.init_params(KEY, cfg)
    # 3x amplification: a random-init model at unit scale greedy-decodes
    # straight into a fixed point (constant streams), which would make
    # the mid-decode EOS scenario unreachable; amplified weights give
    # position-dependent streams while staying deterministic
    params = jax.tree.map(lambda a: a * 3.0, params)
    if packed:
        sasp = SASPConfig(enabled=True, block_k=8, block_n=8,
                          sparsity=0.25, scope="all")
        cfg = dataclasses.replace(cfg, sasp=sasp)
        params, _ = prune_params(params, sasp)
        params, cfg = deploy_packed(params, cfg)
    return cfg, params


def _solo(params, cfg, req: Request):
    r = Request(rid=req.rid, prompt=req.prompt,
                max_new_tokens=req.max_new_tokens, eos_id=req.eos_id)
    return Engine(params, cfg, batch_slots=1, cache_len=64).run(
        [r])[0].out_tokens


@pytest.mark.parametrize("packed", [False, True])
def test_eos_freed_slot_refilled_bit_identical(packed):
    """The continuous-batching contract: request 1 stops early on EOS,
    its slot is refilled from the queue while request 0 still decodes,
    and every greedy stream is bit-identical to the solo single-batch
    engine."""
    cfg, params = _setup(packed=packed)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 64, size=(6 + 3 * i,)).astype(np.int32)
               for i in range(3)]
    reqs = [Request(rid=0, prompt=prompts[0], max_new_tokens=8),
            Request(rid=1, prompt=prompts[1], max_new_tokens=8),
            Request(rid=2, prompt=prompts[2], max_new_tokens=4)]
    # EOS for request 1 = the first greedy token in its stream with no
    # earlier occurrence (so the EOS check fires mid-decode, not at
    # prefill), freeing its slot while request 0 (budget 8) is active
    stream1 = _solo(params, cfg, reqs[1])
    eos_at = next(i for i in range(1, len(stream1) - 1)
                  if stream1[i] not in stream1[:i])
    reqs[1].eos_id = int(stream1[eos_at])
    solo = {r.rid: _solo(params, cfg, r) for r in reqs}
    assert solo[1] == stream1[:eos_at + 1]     # EOS fired early

    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=2, cache_len=64))
    for r in reqs:
        assert sched.submit(r)
    eng = sched.shards[0]
    done, refilled_while_active = [], False
    while sched.has_work():
        finished = sched.step()
        done.extend(finished)
        if any(f.rid == 1 for f in finished):
            # the freed slot must be refilled with request 2 on the very
            # next step, while request 0 is still decoding
            done.extend(sched.step())
            occupants = {r.rid for r in eng.slot_req if r is not None}
            refilled_while_active = {0, 2} <= occupants
    assert refilled_while_active
    assert eng.stats["continuous_refills"] >= 1
    got = {r.rid: r.out_tokens for r in done}
    assert got == solo
    for r in done:
        assert r.t_submit is not None and r.t_done is not None
        assert r.latency is not None and r.latency > 0


def test_two_ranks_share_traffic_and_stay_isolated():
    """Meshless 2-rank scheduler: requests are routed across both engine
    shards (least outstanding work) and every stream still matches the
    solo single-batch engine bit-for-bit."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 64, size=(5 + i,))
                    .astype(np.int32),
                    max_new_tokens=3 + (2 * i) % 5)
            for i in range(6)]
    solo = {r.rid: _solo(params, cfg, r) for r in reqs}
    sched = ShardedScheduler(
        params, cfg, ranks=2,
        sched=SchedulerConfig(slots_per_rank=2, cache_len=64))
    done = sched.run(list(reqs))
    assert {r.rid: r.out_tokens for r in done} == solo
    st = sched.stats()
    assert all(r["admitted"] > 0 for r in st["per_rank"])
    assert {r.rank for r in done} == {0, 1}


def test_admission_control_rejects_beyond_max_queue():
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=(6,))
                    .astype(np.int32), max_new_tokens=3)
            for i in range(5)]
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              max_queue=2))
    # the cap counts waiting work NET of free slots: with 1 free slot
    # and max_queue=2 the burst admits 3 (1 absorbable + 2 waiting)
    accepted = [sched.submit(r) for r in reqs]
    assert accepted == [True, True, True, False, False]
    done = sched.run([])
    assert sorted(r.rid for r in done) == [0, 1, 2]
    st = sched.stats()
    assert st["rejected"] == 2 and st["accepted"] == 3
    assert [r.rid for r in sched.rejected] == [3, 4]


def test_sjf_policy_runs_shortest_queued_request_first():
    cfg, params = _setup()
    prompt = np.arange(1, 7, dtype=np.int32)
    mx = {"long": 8, "short": 2, "mid": 4}

    def completion_order(policy):
        sched = ShardedScheduler(
            params, cfg, ranks=1,
            sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                                  policy=policy))
        sched.submit(Request(rid=0, prompt=prompt,
                             max_new_tokens=mx["long"]))
        sched.submit(Request(rid=1, prompt=prompt,
                             max_new_tokens=mx["short"]))
        sched.submit(Request(rid=2, prompt=prompt,
                             max_new_tokens=mx["mid"]))
        return [r.rid for r in sched.run([])]

    assert completion_order("fcfs") == [0, 1, 2]   # arrival order
    assert completion_order("sjf") == [1, 2, 0]    # shortest first


def test_drain_baseline_takes_more_steps_than_continuous():
    """The drain-batch control: same slots, same requests, strictly more
    decode steps (slots idle while the batch drains) — the effect the
    bench quantifies as tokens/sec under load."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    mx = [8, 3, 6, 4, 7]
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=(5 + i,))
                    .astype(np.int32), max_new_tokens=mx[i])
            for i in range(5)]
    solo = {r.rid: _solo(params, cfg, r) for r in reqs}

    def steps(drain):
        sched = ShardedScheduler(
            params, cfg, ranks=1,
            sched=SchedulerConfig(slots_per_rank=2, cache_len=64,
                                  drain=drain))
        done = sched.run([Request(rid=r.rid, prompt=r.prompt,
                                  max_new_tokens=r.max_new_tokens)
                          for r in reqs])
        assert {r.rid: r.out_tokens for r in done} == solo
        return sched.stats()["per_rank"][0]["decode_steps"]

    assert steps(drain=True) > steps(drain=False)
