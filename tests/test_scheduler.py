"""Sharded request scheduler (DESIGN.md §11–§12): continuous-batching
bit-identity (a slot freed by EOS is refilled from the queue and every
stream matches the solo single-batch engine), per-rank queue sharding,
admission control, SJF vs FCFS vs EDF ordering, aging, preemption
(exact resume via KV snapshot AND re-prefill), prefill bucketing
(bounded jit cache), per-token streaming, rank-failure containment,
and the drain-batch baseline. The 1×2-mesh packed variant of the
bit-identity + streaming contract lives in tests/test_distribution.py
(``sched_mesh`` worker)."""
import dataclasses

import numpy as np
import jax
import pytest

from repro.configs import SASPConfig, get_config, reduced
from repro.core.deploy import deploy_packed
from repro.core.pruning import prune_params
from repro.models import lm
from repro.serve.engine import Engine, Request
from repro.serve.scheduler import SchedulerConfig, ShardedScheduler

KEY = jax.random.PRNGKey(0)


def _setup(packed=False):
    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=64, vocab=64)
    params = lm.init_params(KEY, cfg)
    # 3x amplification: a random-init model at unit scale greedy-decodes
    # straight into a fixed point (constant streams), which would make
    # the mid-decode EOS scenario unreachable; amplified weights give
    # position-dependent streams while staying deterministic
    params = jax.tree.map(lambda a: a * 3.0, params)
    if packed:
        sasp = SASPConfig(enabled=True, block_k=8, block_n=8,
                          sparsity=0.25, scope="all")
        cfg = dataclasses.replace(cfg, sasp=sasp)
        params, _ = prune_params(params, sasp)
        params, cfg = deploy_packed(params, cfg)
    return cfg, params


def _solo(params, cfg, req: Request):
    r = Request(rid=req.rid, prompt=req.prompt,
                max_new_tokens=req.max_new_tokens, eos_id=req.eos_id)
    return Engine(params, cfg, batch_slots=1, cache_len=64).run(
        [r])[0].out_tokens


@pytest.mark.parametrize("packed", [False, True])
def test_eos_freed_slot_refilled_bit_identical(packed):
    """The continuous-batching contract: request 1 stops early on EOS,
    its slot is refilled from the queue while request 0 still decodes,
    and every greedy stream is bit-identical to the solo single-batch
    engine."""
    cfg, params = _setup(packed=packed)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 64, size=(6 + 3 * i,)).astype(np.int32)
               for i in range(3)]
    reqs = [Request(rid=0, prompt=prompts[0], max_new_tokens=8),
            Request(rid=1, prompt=prompts[1], max_new_tokens=8),
            Request(rid=2, prompt=prompts[2], max_new_tokens=4)]
    # EOS for request 1 = the first greedy token in its stream with no
    # earlier occurrence (so the EOS check fires mid-decode, not at
    # prefill), freeing its slot while request 0 (budget 8) is active
    stream1 = _solo(params, cfg, reqs[1])
    eos_at = next(i for i in range(1, len(stream1) - 1)
                  if stream1[i] not in stream1[:i])
    reqs[1].eos_id = int(stream1[eos_at])
    solo = {r.rid: _solo(params, cfg, r) for r in reqs}
    assert solo[1] == stream1[:eos_at + 1]     # EOS fired early

    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=2, cache_len=64))
    for r in reqs:
        assert sched.submit(r)
    eng = sched.shards[0]
    done, refilled_while_active = [], False
    while sched.has_work():
        finished = sched.step()
        done.extend(finished)
        if any(f.rid == 1 for f in finished):
            # the freed slot must be refilled with request 2 on the very
            # next step, while request 0 is still decoding
            done.extend(sched.step())
            occupants = {r.rid for r in eng.slot_req if r is not None}
            refilled_while_active = {0, 2} <= occupants
    assert refilled_while_active
    assert eng.stats["continuous_refills"] >= 1
    got = {r.rid: r.out_tokens for r in done}
    assert got == solo
    for r in done:
        assert r.t_submit is not None and r.t_done is not None
        assert r.latency is not None and r.latency > 0


def test_two_ranks_share_traffic_and_stay_isolated():
    """Meshless 2-rank scheduler: requests are routed across both engine
    shards (least outstanding work) and every stream still matches the
    solo single-batch engine bit-for-bit."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 64, size=(5 + i,))
                    .astype(np.int32),
                    max_new_tokens=3 + (2 * i) % 5)
            for i in range(6)]
    solo = {r.rid: _solo(params, cfg, r) for r in reqs}
    sched = ShardedScheduler(
        params, cfg, ranks=2,
        sched=SchedulerConfig(slots_per_rank=2, cache_len=64))
    done = sched.run(list(reqs))
    assert {r.rid: r.out_tokens for r in done} == solo
    st = sched.stats()
    assert all(r["admitted"] > 0 for r in st["per_rank"])
    assert {r.rank for r in done} == {0, 1}


def test_admission_control_rejects_beyond_max_queue():
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=(6,))
                    .astype(np.int32), max_new_tokens=3)
            for i in range(5)]
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              max_queue=2))
    # the cap counts waiting work NET of free slots: with 1 free slot
    # and max_queue=2 the burst admits 3 (1 absorbable + 2 waiting)
    accepted = [sched.submit(r) for r in reqs]
    assert accepted == [True, True, True, False, False]
    done = sched.run([])
    assert sorted(r.rid for r in done) == [0, 1, 2]
    st = sched.stats()
    assert st["rejected"] == 2 and st["accepted"] == 3
    assert [r.rid for r in sched.rejected] == [3, 4]


def test_sjf_policy_runs_shortest_queued_request_first():
    cfg, params = _setup()
    prompt = np.arange(1, 7, dtype=np.int32)
    mx = {"long": 8, "short": 2, "mid": 4}

    def completion_order(policy):
        sched = ShardedScheduler(
            params, cfg, ranks=1,
            sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                                  policy=policy))
        sched.submit(Request(rid=0, prompt=prompt,
                             max_new_tokens=mx["long"]))
        sched.submit(Request(rid=1, prompt=prompt,
                             max_new_tokens=mx["short"]))
        sched.submit(Request(rid=2, prompt=prompt,
                             max_new_tokens=mx["mid"]))
        return [r.rid for r in sched.run([])]

    assert completion_order("fcfs") == [0, 1, 2]   # arrival order
    assert completion_order("sjf") == [1, 2, 0]    # shortest first


@pytest.mark.parametrize("mode", ["kv", "reprefill"])
def test_preempt_resume_bit_identical(mode):
    """QoS acceptance (DESIGN.md §12): an interactive request evicts a
    mid-decode batch request at step granularity; the victim resumes —
    KV-snapshot restore or re-prefill of prompt + generated tokens —
    and BOTH streams stay bit-identical to the solo single-batch
    engine. The interactive request must also retire first."""
    cfg, params = _setup()
    rng = np.random.default_rng(4)
    batch = Request(rid=0,
                    prompt=rng.integers(0, 64, size=(8,))
                    .astype(np.int32),
                    max_new_tokens=12, slo="batch")
    inter = Request(rid=1,
                    prompt=rng.integers(0, 64, size=(6,))
                    .astype(np.int32),
                    max_new_tokens=3, slo="interactive", deadline=0.01)
    solo = {r.rid: _solo(params, cfg, r) for r in (batch, inter)}

    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              policy="edf", preempt=True,
                              preempt_mode=mode))
    assert sched.submit(batch)
    for _ in range(4):              # batch decodes a while first
        sched.step()
    assert sched.submit(inter)      # late interactive arrival
    done = []
    while sched.has_work():
        done.extend(sched.step())

    st = sched.stats()
    assert st["preemptions"] >= 1
    assert st["per_rank"][0]["resumes"] >= 1
    assert batch.preemptions >= 1 and inter.preemptions == 0
    order = [r.rid for r in done]
    assert order.index(1) < order.index(0), order
    assert {r.rid: r.out_tokens for r in done} == solo
    assert batch.status == "done" and inter.status == "done"
    assert batch._kv is None        # resume state fully cleared


def test_edf_orders_by_deadline_and_aging_prevents_starvation():
    """policy='edf': tight-deadline interactive requests run before a
    long-deadline batch request submitted earlier (pure EDF, aging=0);
    with a large aging credit, waiting time dominates and the oldest
    (batch) request runs first — the anti-starvation knob."""
    cfg, params = _setup()
    prompt = np.arange(1, 7, dtype=np.int32)

    def completion_order(aging):
        sched = ShardedScheduler(
            params, cfg, ranks=1,
            sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                                  policy="edf", aging=aging))
        sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=2,
                             slo="batch"))            # earliest arrival
        for i in (1, 2):
            sched.submit(Request(rid=i, prompt=prompt, max_new_tokens=2,
                                 slo="interactive", deadline=0.05))
        return [r.rid for r in sched.run([])]

    assert completion_order(aging=0.0) == [1, 2, 0]    # pure EDF
    # huge credit (1e9 s of deadline per second waited: even a µs of
    # extra wait outweighs the 30s deadline gap): arrival order wins
    # and the batch request is never starved
    assert completion_order(aging=1e9) == [0, 1, 2]


def _faulty_decode(eng, after=3, msg="injected shard fault"):
    """Replace eng._decode with one that raises from the ``after``-th
    call on (the shard dies mid-load, not at startup)."""
    calls = {"n": 0}
    orig = eng._decode

    def faulty(*a, **k):
        calls["n"] += 1
        if calls["n"] >= after:
            raise RuntimeError(msg)
        return orig(*a, **k)

    eng._decode = faulty


def test_rank_failure_requeues_inflight_bit_identical():
    """Requeue-on-failure (DESIGN.md §14): an engine shard raising
    mid-step evacuates its IN-FLIGHT requests to the surviving rank
    with an exact re-prefill resume armed on their emitted-token
    snapshot — every request completes (nothing terminally fails) and
    every greedy stream, including the mid-decode casualties', is
    bit-identical to the solo single-batch engine."""
    cfg, params = _setup()
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=(6,))
                    .astype(np.int32), max_new_tokens=6)
            for i in range(6)]
    solo = {r.rid: _solo(params, cfg, r) for r in reqs}
    sched = ShardedScheduler(
        params, cfg, ranks=2,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64))
    eng0 = sched.shards[0]
    _faulty_decode(eng0)
    done = sched.run(reqs)

    st = sched.stats()
    assert st["live_ranks"] == 1 and eng0.dead
    assert st["requeued"] >= 1      # an in-flight request was evacuated
    assert not sched.failed         # …and nothing failed terminally
    assert len(done) == len(reqs)
    assert {r.rid: r.out_tokens for r in done} == solo
    assert all(r.status == "done" for r in reqs)
    assert not eng0.queue           # dead rank's queue was re-routed
    assert max(r.requeues for r in reqs) >= 1
    # the survivor took over and actually served traffic
    assert sched.shards[1].stats["admitted"] >= len(done)


def test_rank_failure_terminal_without_requeue():
    """requeue_inflight=False keeps the PR-4 containment: a shard
    raising mid-step fails ONLY its in-flight requests (status + error
    surfaced on the Request), its queued requests re-route to the
    surviving rank, and the serving loop terminates (no deadlock on
    the admission queue)."""
    cfg, params = _setup()
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=(6,))
                    .astype(np.int32), max_new_tokens=6)
            for i in range(6)]
    sched = ShardedScheduler(
        params, cfg, ranks=2,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              requeue_inflight=False))
    eng0 = sched.shards[0]
    _faulty_decode(eng0)
    done = sched.run(reqs)

    st = sched.stats()
    assert st["live_ranks"] == 1 and eng0.dead
    assert len(sched.failed) >= 1
    for r in sched.failed:
        assert r.status == "failed"
        assert "injected shard fault" in r.error
    # every request resolved exactly one way — no deadlock, no loss
    assert len(done) + len(sched.failed) == len(reqs)
    assert all(r.status == "done" for r in done)
    assert not eng0.queue           # dead rank's queue was re-routed
    # the survivor took over and actually served traffic
    assert sched.shards[1].stats["admitted"] >= len(done)


def test_max_requeues_bounds_poison_request():
    """A request that keeps killing ranks must fail for real once its
    requeue budget is spent, instead of cycling through revived shards
    forever — but only after it actually got max_requeues fresh
    chances on other ranks."""
    cfg, params = _setup()
    rng = np.random.default_rng(14)
    req = Request(rid=0, prompt=rng.integers(0, 64, size=(6,))
                  .astype(np.int32), max_new_tokens=8)
    sched = ShardedScheduler(
        params, cfg, ranks=4,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              max_requeues=2))
    for eng in sched.shards:        # every rank dies on its 2nd decode
        _faulty_decode(eng, after=2, msg="poison")
    done = sched.run([req])
    assert not done
    assert req.status == "failed" and "requeue(s) exhausted" in req.error
    assert req.requeues == 3        # initial run + 2 requeued attempts
    assert sched.stats()["requeued"] == 2


@pytest.mark.slow
def test_rank_failure_during_admission_requeues_popped_requests():
    """A shard raising inside ADMISSION (jitted prefill) must not lose
    the requests it had already popped off its queue: they return to
    the queue and re-route to the survivor, so every request still
    resolves as done or failed."""
    cfg, params = _setup()
    rng = np.random.default_rng(8)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=(6,))
                    .astype(np.int32), max_new_tokens=4)
            for i in range(6)]
    sched = ShardedScheduler(
        params, cfg, ranks=2,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64))
    eng0 = sched.shards[0]
    calls = {"n": 0}
    orig = eng0._prefill

    def faulty(*a):
        calls["n"] += 1
        if calls["n"] >= 2:       # first admission fine, refill raises
            raise RuntimeError("injected prefill fault")
        return orig(*a)

    eng0._prefill = faulty
    done = sched.run(reqs)
    assert eng0.dead and not eng0.queue
    assert len(done) + len(sched.failed) == len(reqs)
    statuses = {r.rid: r.status for r in reqs}
    assert all(s in ("done", "failed") for s in statuses.values()), \
        statuses


def test_total_failure_resolves_every_request():
    """All ranks dead mid-run with arrivals still pending: the pending
    requests must still resolve (status 'failed' on scheduler.failed),
    never stranded as status 'new' — and run() must return."""
    cfg, params = _setup()
    rng = np.random.default_rng(10)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=(6,))
                    .astype(np.int32), max_new_tokens=4)
            for i in range(5)]
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64))
    eng = sched.shards[0]
    eng._decode = lambda *a, **k: (_ for _ in ()).throw(
        RuntimeError("total failure"))
    # last arrival far in the future: the rank dies long before it
    done = sched.run(reqs, arrivals=[0.0, 0.0, 0.0, 5.0, 9.0])
    assert len(done) + len(sched.failed) == len(reqs)
    assert all(r.status in ("done", "failed") for r in reqs), \
        {r.rid: r.status for r in reqs}
    assert all(r.error for r in sched.failed)


def test_preempt_effective_under_fcfs_policy():
    """--preempt with the default fcfs policy: the preemption-
    triggering interactive request (not an older queued batch request)
    must get the freed slot, or the eviction is wasted work."""
    cfg, params = _setup()
    rng = np.random.default_rng(9)
    mk = lambda rid, new, slo, dl: Request(
        rid=rid, prompt=rng.integers(0, 64, size=(6,)).astype(np.int32),
        max_new_tokens=new, slo=slo, deadline=dl)
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                              policy="fcfs", preempt=True))
    running = mk(0, 10, "batch", 30.0)
    queued_batch = mk(1, 4, "batch", 30.0)
    sched.submit(running)
    sched.step()                    # rid 0 occupies the only slot
    sched.submit(queued_batch)      # older queued batch request
    inter = mk(2, 2, "interactive", 0.01)
    sched.submit(inter)
    done = []
    while sched.has_work():
        done.extend(sched.step())
    assert sched.stats()["preemptions"] >= 1
    order = [r.rid for r in done]
    assert order.index(2) < order.index(0), order
    assert order.index(2) < order.index(1), order


def test_jit_cache_bounded_by_buckets_under_random_lengths():
    """Acceptance (DESIGN.md §12): with prefill bucketing, ≥50 random
    prompt lengths compile at most len(buckets) admission programs
    (every admission shape is (B, bucket)), and the streams stay
    bit-identical to the unbucketed engine.  The measured shape set is
    also cross-checked against the static analyzer's recompile-budget
    prediction (tools/analyze/recompile.py) — the two models of the
    admission jit cache must agree."""
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from tools.analyze.recompile import budget_for, predict_prefill_shapes

    cfg, params = _setup()
    buckets = (8, 16, 32, 64)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, 64, size=(int(rng.integers(2, 60)),))
               .astype(np.int32) for _ in range(50)]

    def run(eng):
        shapes = set()
        orig = eng._prefill

        def counting(params, toks, poss, caches, slots, valid):
            shapes.add(tuple(toks.shape))
            return orig(params, toks, poss, caches, slots, valid)

        eng._prefill = counting
        done = eng.run([Request(rid=i, prompt=p, max_new_tokens=2)
                        for i, p in enumerate(prompts)])
        return {r.rid: r.out_tokens for r in done}, shapes

    plain, _ = run(Engine(params, cfg, batch_slots=2, cache_len=64))
    bucketed, shapes = run(Engine(params, cfg, batch_slots=2,
                                  cache_len=64, buckets=buckets))
    assert bucketed == plain
    assert len(shapes) <= len(buckets), shapes
    # fixed group size: every admission pass is (B, bucket)
    assert all(g == 2 and s in buckets for g, s in shapes), shapes

    # static analyzer agreement: the measured compile set is contained
    # in the prediction and bounded by the documented budget
    predicted = predict_prefill_shapes(buckets, 2,
                                       [len(p) for p in prompts])
    assert shapes <= predicted, shapes - predicted
    assert len(shapes) <= budget_for(buckets, 64)

    # deterministic coverage: one solo admission per bucket makes the
    # measured set EQUAL the static prediction, not just a subset
    lengths = (4, 12, 20, 40)
    eng = Engine(params, cfg, batch_slots=1, cache_len=64,
                 buckets=buckets)
    solo_shapes = set()
    orig = eng._prefill

    def counting(params_, toks, poss, caches, slots, valid):
        solo_shapes.add(tuple(toks.shape))
        return orig(params_, toks, poss, caches, slots, valid)

    eng._prefill = counting
    eng.run([Request(rid=100 + i,
                     prompt=rng.integers(0, 64, size=(L,))
                     .astype(np.int32), max_new_tokens=2)
             for i, L in enumerate(lengths)])
    assert solo_shapes == predict_prefill_shapes(buckets, 1, lengths)


@pytest.mark.slow
def test_scheduler_streaming_matches_out_tokens():
    """stream() yields every sampled token exactly once, per-request
    order preserved, across 2 ranks — and the per-rid sequences equal
    both Request.out_tokens and the solo engine streams."""
    cfg, params = _setup()
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=(5 + i,))
                    .astype(np.int32), max_new_tokens=4)
            for i in range(4)]
    solo = {r.rid: _solo(params, cfg, r) for r in reqs}
    sched = ShardedScheduler(
        params, cfg, ranks=2,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64))
    per = {}
    for rid, tok in sched.stream(reqs):
        per.setdefault(rid, []).append(tok)
    assert per == solo
    assert {r.rid: r.out_tokens for r in reqs} == solo
    assert all(r.done and r.status == "done" for r in reqs)
    for e in sched.shards:          # sink detached after the loop
        assert e.on_token is None


def test_deadline_shed_improves_interactive_attainment():
    """Deadline-aware admission shedding (ROADMAP): under overload the
    'deadline' policy evicts the waiting BATCH request least likely to
    meet its deadline instead of rejecting the newcomer, so a late
    interactive burst is admitted and its SLO attainment beats FCFS
    count-shedding at the same max_queue."""
    cfg, params = _setup()
    rng = np.random.default_rng(12)
    mk = lambda rid, slo, dl, new: Request(
        rid=rid, prompt=rng.integers(0, 64, size=(6,)).astype(np.int32),
        max_new_tokens=new, slo=slo, deadline=dl)

    def attainment(shed):
        sched = ShardedScheduler(
            params, cfg, ranks=1,
            sched=SchedulerConfig(slots_per_rank=1, cache_len=64,
                                  max_queue=3, shed=shed))
        # batch flood fills the queue past the cap…
        for i in range(5):
            sched.submit(mk(i, "batch", 30.0, 8))
        # …then the interactive burst arrives (generous deadline: an
        # admitted interactive request always attains its SLO here, so
        # attainment == admission under overload)
        inter = [mk(10 + i, "interactive", 10.0, 2) for i in range(3)]
        for r in inter:
            sched.submit(r)
        done = {r.rid for r in sched.run([])}
        met = sum(1 for r in inter
                  if r.rid in done and r.latency <= 10.0)
        return met / len(inter), sched

    fcfs_att, s0 = attainment("count")
    edf_att, s1 = attainment("deadline")
    assert fcfs_att == 0.0          # count-shed rejects the late burst
    assert edf_att == 1.0, s1.stats()
    assert s1.n_shed >= 3           # batch victims evicted instead
    for r in s1.rejected:           # victims resolved, never stranded
        assert r.status == "rejected" and r.slo == "batch"


def test_revive_rank_rebuilds_dead_shard_and_serves_again():
    """Engine-raise recovery (ROADMAP): a rank killed by an injected
    fault is rebuilt by revive_rank — fresh caches, re-placed params —
    re-enters routing, and serves bit-identical streams again. The
    revived shard inherits the dead one's cumulative counters (stats
    continuity across the outage, DESIGN.md §14) instead of resetting
    to zero."""
    cfg, params = _setup()
    rng = np.random.default_rng(13)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=(6 + i,))
                    .astype(np.int32), max_new_tokens=4)
            for i in range(3)]
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=2, cache_len=64))
    eng0 = sched.shards[0]
    eng0._decode = lambda *a, **k: (_ for _ in ()).throw(
        RuntimeError("injected rank death"))
    sched.run(reqs[:1])
    assert eng0.dead and sched.stats()["live_ranks"] == 0
    assert eng0.stats["admitted"] == 1 and eng0.stats["deaths"] == 1
    # a submission while dead fails fast (no live shards)…
    assert not sched.submit(reqs[1])
    assert reqs[1].status == "failed"

    revived = sched.revive_rank(0)
    assert revived is sched.shards[0] and not revived.dead
    assert sched.stats()["live_ranks"] == 1
    assert sched.stats()["revived"] == 1
    # …and the revived shard serves bit-identically
    solo = _solo(params, cfg, reqs[2])
    done = sched.run([reqs[2]])
    assert len(done) == 1 and done[0].out_tokens == solo
    # stats continuity: the pre-death admission is still counted, the
    # outage is, and new traffic accumulates on top
    assert revived.stats["admitted"] == 2
    assert revived.stats["deaths"] == 1


def test_revive_rank_refuses_live_shard():
    cfg, params = _setup()
    sched = ShardedScheduler(
        params, cfg, ranks=1,
        sched=SchedulerConfig(slots_per_rank=1, cache_len=64))
    with pytest.raises(ValueError, match="alive"):
        sched.revive_rank(0)


def test_route_steers_away_from_rank_mid_spill():
    """Spill-aware routing (ROADMAP item 2): a paged rank whose
    below-watermark residency headroom cannot cover the newcomer's
    prefill is mid-spill — it loses routing to a rank WITH headroom
    even when its outstanding-token load is lower. Contiguous ranks
    (no page pool) keep the pure least-outstanding-work policy."""
    cfg, params = _setup()
    rng = np.random.default_rng(15)
    mk = lambda rid, plen, new: Request(
        rid=rid, prompt=rng.integers(0, 64, size=(plen,))
        .astype(np.int32), max_new_tokens=new)

    def build(paged):
        kv = dict(kv_pages=8, kv_page_len=8) if paged else {}
        sched = ShardedScheduler(
            params, cfg, ranks=2,
            sched=SchedulerConfig(slots_per_rank=2, cache_len=64, **kv))
        # rank 0: little remaining work but a prompt holding most of its
        # page pool; rank 1: heavy decode backlog, pool nearly empty
        sched.shards[0].submit(mk(0, 40, 4))
        sched.shards[1].submit(mk(1, 8, 40))
        sched.step()
        return sched

    newcomer = mk(2, 30, 4)
    paged = build(paged=True)
    assert paged.shards[0].outstanding_tokens() \
        < paged.shards[1].outstanding_tokens()
    h0 = paged.shards[0].route_headroom_tokens()
    assert h0 is not None and h0 < len(newcomer.prompt)
    assert paged._route(newcomer) is paged.shards[1]
    assert paged.submit(newcomer) and newcomer.rank == 1
    done = paged.run([])
    assert sorted(r.rid for r in done) == [0, 1, 2]

    contig = build(paged=False)     # no pool: least outstanding wins
    assert contig.shards[0].route_headroom_tokens() is None
    assert contig._route(newcomer) is contig.shards[0]


def test_drain_baseline_takes_more_steps_than_continuous():
    """The drain-batch control: same slots, same requests, strictly more
    decode steps (slots idle while the batch drains) — the effect the
    bench quantifies as tokens/sec under load."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    mx = [8, 3, 6, 4, 7]
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=(5 + i,))
                    .astype(np.int32), max_new_tokens=mx[i])
            for i in range(5)]
    solo = {r.rid: _solo(params, cfg, r) for r in reqs}

    def steps(drain):
        sched = ShardedScheduler(
            params, cfg, ranks=1,
            sched=SchedulerConfig(slots_per_rank=2, cache_len=64,
                                  drain=drain))
        done = sched.run([Request(rid=r.rid, prompt=r.prompt,
                                  max_new_tokens=r.max_new_tokens)
                          for r in reqs])
        assert {r.rid: r.out_tokens for r in done} == solo
        return sched.stats()["per_rank"][0]["decode_steps"]

    assert steps(drain=True) > steps(drain=False)
