"""Paged-KV refcount invariant helper (DESIGN.md §16).

Device-free validation of a live :class:`repro.serve.memory`
``PageAllocator`` (or the ``PagedKVPool`` wrapping one), in the style
of the packed-format validators in :mod:`tools.analyze.packed`: plain
host-side bookkeeping checks that tests and chaos harnesses can run
after every adversarial event (kill/revive, preempt storm, forced
spill) without touching the accelerator.

Unlike the five analyzer passes this is NOT a registered static-
analysis rule — there is no source file to scan; the subject is a
runtime object.  ``check_page_refcounts`` returns a list of error
strings (empty = healthy) instead of asserting, so a harness can
attach context before failing:

    errs = check_page_refcounts(engine.pool)
    assert not errs, errs

Invariants (the prose form of ``PageAllocator.check``):

* refcount == number of block-table references, for every owned page
* device pages partition exactly into {owned} ∪ {free} ∪ {cached} —
  no leaks, no double-frees
* host slots partition into {spilled refs} ∪ {free}
* high watermark respected (``used_dev <= cap``)
* every cached (rc-0, LRU-evictable) page is registered in the radix
  index, every registered page is device-resident, nodes point back
  at their page
* share disabled ⇒ no radix state and every refcount is exactly 1
* speculative scratch pages (DESIGN.md §17) are held only by resident
  requests, carry no refcount, and are never registered — they join
  the device-page partition but stay invisible to sharing
"""

from __future__ import annotations

from typing import Dict, List


def check_page_refcounts(pool_or_alloc) -> List[str]:
    """Validate refcount/partition invariants. Returns error strings
    (empty list = all invariants hold). Accepts a ``PagedKVPool``, a
    bare ``PageAllocator``, or ``None`` (contiguous engine — nothing
    to check)."""
    if pool_or_alloc is None:
        return []
    a = getattr(pool_or_alloc, "alloc", pool_or_alloc)
    errs: List[str] = []

    ref_count: Dict[int, int] = {}
    owned_host: List[int] = []
    for rid, refs in a.tables.items():
        for e in refs:
            if e is None:
                continue
            if e[0] == "dev":
                ref_count[e[1]] = ref_count.get(e[1], 0) + 1
            else:
                owned_host.append(e[1])

    if ref_count != a.rc:
        errs.append(f"refcount != block-table references: "
                    f"rc={a.rc} vs tables={ref_count}")
    scratch = getattr(a, "scratch", {})
    scratch_pages = [p for d in scratch.values() for p in d.values()]
    seen = sorted(list(ref_count) + list(a.free_dev) + list(a.cached)
                  + scratch_pages)
    if seen != a._all_dev:
        errs.append(f"device pages leaked or double-owned: "
                    f"owned+free+cached+scratch={seen} "
                    f"vs all={a._all_dev}")
    for rid, d in scratch.items():
        if rid not in a.resident:
            errs.append(f"scratch held by non-resident rid {rid}")
        for p in d.values():
            if p in a.rc or p in a._node_of:
                errs.append(f"scratch page {p} owned or registered")
    if sorted(owned_host + list(a.free_host)) != list(range(a.n_host)):
        errs.append(f"host slots leaked or double-owned: "
                    f"owned={sorted(owned_host)} free={a.free_host}")
    if len(set(owned_host)) != len(owned_host):
        errs.append(f"host slot double-referenced: {sorted(owned_host)}")
    if a.used_dev > a.cap:
        errs.append(f"watermark breached: {a.used_dev} > cap {a.cap}")
    if not set(a.preempted).isdisjoint(a.resident):
        errs.append("request both resident and preempted: "
                    f"{set(a.preempted) & a.resident}")
    if set(a.tables) != a.resident | set(a.preempted):
        errs.append("table set != resident ∪ preempted")
    for rid in a.resident:
        if any(e is not None and e[0] != "dev" for e in a.tables[rid]):
            errs.append(f"resident rid {rid} holds spilled pages")
    if len(set(a.cached)) != len(a.cached):
        errs.append(f"cached LRU holds duplicates: {a.cached}")
    for p in a.cached:
        if p not in a._node_of:
            errs.append(f"cached page {p} not in the radix index")
    for p, node in a._node_of.items():
        if node.page != p:
            errs.append(f"radix node for page {p} points at "
                        f"{node.page}")
        if p not in a.rc and p not in a.cached:
            errs.append(f"registered page {p} neither owned nor cached")
    if not a.share:
        if a._node_of or a.cached:
            errs.append("share disabled but radix state exists")
        bad = {p: c for p, c in a.rc.items() if c != 1}
        if bad:
            errs.append(f"share disabled but refcounts != 1: {bad}")
    return errs
