"""Pass 4 — concurrency lint for the threaded serving layer.

A declared lock-protection map (attribute → owning lock) for
``serve/frontend.py`` and ``serve/scheduler.py`` drives two checks:

* **LOCK-UNHELD** — a read/write of a protected shared attribute on a
  path that does not hold the owning lock.  "Holds" is computed
  lexically (inside ``with self._lock:``) plus a fixpoint over the
  intra-class call graph: an internal method inherits the lock when
  EVERY call site (transitively) holds it; methods reachable from
  outside the class (declared ``entry_points``, or never called
  intra-class) must guard their own accesses.  ``__init__`` is exempt
  (the object is not shared yet).  Cross-object accesses
  (``self.sched.failed`` from the frontend) are flagged unless made
  through an owner method — foreign locks cannot be held implicitly.

* **LOCK-ORDER** — collects ordered (held → acquired) lock pairs
  across the heartbeat, reader-thread, and drain paths (including
  cross-class edges like frontend.step → scheduler.step) and reports
  any pair contradicting the declared hierarchy, or A→B and B→A both
  observed when no hierarchy is declared.
"""

from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .common import Finding, Module, relpath, REPO_ROOT
from .rules import LOCK_ORDER, LOCK_UNHELD

# ---------------------------------------------------------------------------
# declared lock-protection map for the repo's threaded serving layer
# ---------------------------------------------------------------------------
# Per file, per class:
#   lock         -- attribute name of the owning threading.(R)Lock
#   protected    -- attributes that must only be touched under the lock
#   entry_points -- methods callable from outside the class (or from
#                   other threads); these must guard their own accesses
#   attr_classes -- local attribute -> (file, class) of a foreign object
#                   whose protected attributes must not be poked directly
REPO_LOCK_SPECS: Dict[str, Dict[str, Dict]] = {
    "src/repro/serve/frontend.py": {
        "ClusterFrontend": {
            "lock": "_lock",
            "protected": {
                "trackers", "done", "failed", "rejected", "draining",
                "n_retries", "n_deduped", "_health",
            },
            "entry_points": {
                "submit", "step", "run", "drain", "revive_host",
                "stats", "unresolved", "close", "_local_sink",
            },
        },
        "LocalHost": {
            "attr_classes": {
                "sched": ("src/repro/serve/scheduler.py",
                          "ShardedScheduler"),
            },
        },
    },
    "src/repro/serve/scheduler.py": {
        "ShardedScheduler": {
            "lock": "_lock",
            "protected": {
                "n_submitted", "n_accepted", "n_shed", "n_revived",
                "n_requeued", "rejected", "failed", "prompt_hist",
            },
            "entry_points": {
                "submit", "step", "revive_rank", "stats", "cancel",
                "drain_failed", "retract_request",
                "prompt_length_histogram",
            },
        },
    },
}

# Declared global acquisition hierarchy: a lock may only be acquired
# while holding locks that appear EARLIER in this list.
REPO_LOCK_ORDER: List[str] = [
    "ClusterFrontend._lock",
    "ShardedScheduler._lock",
]


class _Access(Tuple):
    pass


class _MethodScan(ast.NodeVisitor):
    """Walk one method body tracking the lexically-held lock set."""

    def __init__(self, lock_name: Optional[str], cls_label: str,
                 attr_classes: Dict[str, Tuple[str, str]],
                 specs_by_file: Dict[str, Dict[str, Dict]]):
        self.lock_name = lock_name
        self.cls_label = cls_label
        self.attr_classes = attr_classes
        self.specs_by_file = specs_by_file
        self.held: Set[str] = set()
        # (attr, lineno, held_own_lock)
        self.accesses: List[Tuple[str, int, bool]] = []
        # (method_name, lineno, held_own_lock)
        self.self_calls: List[Tuple[str, int, bool]] = []
        # (held_lock_label, acquired_lock_label, lineno)
        self.acquire_edges: List[Tuple[str, str, int]] = []
        # foreign accesses: (target_file, target_cls, attr, lineno, guarded)
        self.foreign: List[Tuple[str, str, str, int, bool]] = []
        # cross-class method calls: (target_file, target_cls, method,
        #                            lineno, held_set)
        self.foreign_calls: List[Tuple[str, str, str, int,
                                       Tuple[str, ...]]] = []
        # every lock label this method body acquires anywhere
        self.acquired_any: Set[str] = set()

    # -- helpers ------------------------------------------------------------

    def _own_label(self) -> str:
        return "%s.%s" % (self.cls_label, self.lock_name)

    def _lock_of(self, expr: ast.AST) -> Optional[str]:
        """Label if ``expr`` is self.<lock> or self.<attr>.<foreignlock>."""
        if not isinstance(expr, ast.Attribute):
            return None
        base = expr.value
        if (isinstance(base, ast.Name) and base.id == "self"
                and expr.attr == self.lock_name):
            return self._own_label()
        if (isinstance(base, ast.Attribute)
                and isinstance(base.value, ast.Name)
                and base.value.id == "self"
                and base.attr in self.attr_classes):
            tfile, tcls = self.attr_classes[base.attr]
            tspec = self.specs_by_file.get(tfile, {}).get(tcls, {})
            if expr.attr == tspec.get("lock"):
                return "%s.%s" % (tcls, expr.attr)
        return None

    # -- visitors -----------------------------------------------------------

    def visit_With(self, node: ast.With) -> None:
        acquired: List[str] = []
        for item in node.items:
            label = self._lock_of(item.context_expr)
            if label is not None:
                for h in self.held:
                    if h != label:
                        self.acquire_edges.append(
                            (h, label, node.lineno))
                acquired.append(label)
                self.acquired_any.add(label)
        for item in node.items:
            if self._lock_of(item.context_expr) is None:
                self.visit(item.context_expr)
        self.held.update(acquired)
        for stmt in node.body:
            self.visit(stmt)
        self.held.difference_update(acquired)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        base = node.value
        if isinstance(base, ast.Name) and base.id == "self":
            self.accesses.append(
                (node.attr, node.lineno, self._own_label() in self.held
                 or self.lock_name is None))
        elif (isinstance(base, ast.Attribute)
              and isinstance(base.value, ast.Name)
              and base.value.id == "self"
              and base.attr in self.attr_classes):
            tfile, tcls = self.attr_classes[base.attr]
            tspec = self.specs_by_file.get(tfile, {}).get(tcls, {})
            flabel = "%s.%s" % (tcls, tspec.get("lock"))
            self.foreign.append(
                (tfile, tcls, node.attr, node.lineno,
                 flabel in self.held))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if (isinstance(fn, ast.Attribute)
                and isinstance(fn.value, ast.Name)
                and fn.value.id == "self"):
            self.self_calls.append(
                (fn.attr, node.lineno, self._own_label() in self.held))
        elif (isinstance(fn, ast.Attribute)
              and isinstance(fn.value, ast.Attribute)
              and isinstance(fn.value.value, ast.Name)
              and fn.value.value.id == "self"
              and fn.value.attr in self.attr_classes):
            tfile, tcls = self.attr_classes[fn.value.attr]
            self.foreign_calls.append(
                (tfile, tcls, fn.attr, node.lineno,
                 tuple(sorted(self.held))))
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # nested defs (callbacks) inherit the lexical held set only if
        # called inline; be conservative: treat as NOT holding the lock
        saved = set(self.held)
        self.held = set()
        for stmt in node.body:
            self.visit(stmt)
        self.held = saved

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda) -> None:
        saved = set(self.held)
        self.held = set()
        self.visit(node.body)
        self.held = saved


def _scan_class(mod: Module, cls_name: str, spec: Dict,
                specs_by_file: Dict[str, Dict[str, Dict]]):
    """Per-method scan results for one class."""
    methods = mod.classes.get(cls_name, {})
    out: Dict[str, _MethodScan] = {}
    for mname, mnode in methods.items():
        sc = _MethodScan(spec.get("lock"), cls_name,
                         spec.get("attr_classes", {}), specs_by_file)
        for stmt in mnode.body:
            sc.visit(stmt)
        out[mname] = sc
    return out


def _entry_held_fixpoint(scans: Dict[str, "_MethodScan"],
                         entry_points: Set[str]) -> Dict[str, bool]:
    """entry_held[m]: is the class lock guaranteed held on every path
    that can enter m?  Entry points and never-called methods: False."""
    called_from: Dict[str, List[Tuple[str, bool]]] = {}
    for caller, sc in scans.items():
        for callee, _line, held in sc.self_calls:
            if callee in scans:
                called_from.setdefault(callee, []).append((caller, held))

    entry_held = {m: (m not in entry_points and m in called_from
                      and m != "__init__")
                  for m in scans}
    changed = True
    while changed:
        changed = False
        for m in scans:
            if not entry_held[m]:
                continue
            ok = all(held or entry_held[caller]
                     for caller, held in called_from.get(m, []))
            if not ok:
                entry_held[m] = False
                changed = True
    return entry_held


def run(root: str = REPO_ROOT,
        specs: Optional[Dict[str, Dict[str, Dict]]] = None,
        lock_order: Optional[List[str]] = None) -> List[Finding]:
    specs = REPO_LOCK_SPECS if specs is None else specs
    lock_order = REPO_LOCK_ORDER if lock_order is None else lock_order
    findings: List[Finding] = []
    edges: List[Tuple[str, str, str, int]] = []   # (rel, held, acq, line)

    all_scans: Dict[Tuple[str, str], Dict[str, _MethodScan]] = {}
    mods: Dict[str, Module] = {}
    for rel, classes in specs.items():
        path = os.path.join(root, rel)
        if not os.path.exists(path):
            continue
        mod = Module(path, root)
        mods[rel] = mod
        for cls_name, spec in classes.items():
            all_scans[(rel, cls_name)] = _scan_class(
                mod, cls_name, spec, specs)

    for (rel, cls_name), scans in all_scans.items():
        spec = specs[rel][cls_name]
        protected: Set[str] = set(spec.get("protected", ()))
        entry_points: Set[str] = set(spec.get("entry_points", ()))
        entry_held = _entry_held_fixpoint(scans, entry_points)

        for mname, sc in scans.items():
            if mname == "__init__":
                continue
            guarded = entry_held.get(mname, False)
            if protected and spec.get("lock"):
                for attr, line, held in sc.accesses:
                    if attr in protected and not (held or guarded):
                        findings.append(Finding(
                            LOCK_UNHELD, rel, line,
                            "%s.%s touches shared attribute '%s' "
                            "without holding %s.%s"
                            % (cls_name, mname, attr, cls_name,
                               spec["lock"])))
            # cross-object pokes at another class's protected state
            for tfile, tcls, attr, line, fheld in sc.foreign:
                tspec = specs.get(tfile, {}).get(tcls, {})
                if attr in tspec.get("protected", ()) and not fheld:
                    findings.append(Finding(
                        LOCK_UNHELD, rel, line,
                        "%s.%s touches %s.%s directly — use an owner "
                        "method that holds %s.%s"
                        % (cls_name, mname, tcls, attr, tcls,
                           tspec.get("lock"))))
            # lock-order edges: lexical acquires...
            for held, acq, line in sc.acquire_edges:
                edges.append((rel, held, acq, line))
            # ...and cross-class calls made while holding our lock into
            # methods that acquire the foreign lock
            own = "%s.%s" % (cls_name, spec.get("lock")) \
                if spec.get("lock") else None
            for tfile, tcls, meth, line, held_set in sc.foreign_calls:
                tspec = specs.get(tfile, {}).get(tcls, {})
                tlock = tspec.get("lock")
                if tlock is None:
                    continue
                tscans = all_scans.get((tfile, tcls), {})
                tsc = tscans.get(meth)
                if tsc is None:
                    continue
                tlabel = "%s.%s" % (tcls, tlock)
                acquires = any(
                    acq == tlabel for _h, acq, _l in tsc.acquire_edges
                ) or any(h == tlabel for h, _a, _l in tsc.acquire_edges)
                # a method whose body has `with self._lock` at all:
                acquires = acquires or _acquires_own(tsc, tlabel)
                if not acquires:
                    continue
                for h in held_set:
                    if h != tlabel:
                        edges.append((rel, h, tlabel, line))
            # entry-held methods imply our own lock is held when they
            # run; their foreign calls were recorded with the lexical
            # held set only — add the implied edge
            if own is not None and entry_held.get(mname, False):
                for tfile, tcls, meth, line, held_set in sc.foreign_calls:
                    tspec = specs.get(tfile, {}).get(tcls, {})
                    tlock = tspec.get("lock")
                    if tlock is None:
                        continue
                    tsc = all_scans.get((tfile, tcls), {}).get(meth)
                    if tsc is None or not _acquires_own(
                            tsc, "%s.%s" % (tcls, tlock)):
                        continue
                    edges.append((rel, own, "%s.%s" % (tcls, tlock),
                                  line))

    # ---- order check ------------------------------------------------------
    rank = {label: i for i, label in enumerate(lock_order)}
    seen_pairs: Dict[Tuple[str, str], Tuple[str, int]] = {}
    for rel, held, acq, line in edges:
        seen_pairs.setdefault((held, acq), (rel, line))
        if held in rank and acq in rank and rank[acq] < rank[held]:
            findings.append(Finding(
                LOCK_ORDER, rel, line,
                "acquires %s while holding %s — contradicts the "
                "declared hierarchy %s" % (acq, held,
                                           " -> ".join(lock_order))))
    for (a, b), (rel, line) in seen_pairs.items():
        if (b, a) in seen_pairs and a < b and not (
                a in rank and b in rank):
            findings.append(Finding(
                LOCK_ORDER, rel, line,
                "inconsistent acquisition order between %s and %s "
                "(both orders observed)" % (a, b)))
    return findings


def _acquires_own(sc: _MethodScan, label: str) -> bool:
    """Does the scanned method body contain `with <lock matching label>`
    anywhere?  acquire_edges only records NESTED acquires, so re-derive
    from the recorded edges plus a cheap flag."""
    if any(acq == label for _h, acq, _l in sc.acquire_edges):
        return True
    return label in getattr(sc, "acquired_any", set())
