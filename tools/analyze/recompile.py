"""Pass 3 — recompile-budget checker.

The serving engine's jitted admission is only bounded because prefill
shapes are bucketed (DESIGN.md §12): with a bucket table the admission
pass always runs at ``(batch_slots, bucket)`` shapes, so the jit cache
holds at most ``len(buckets)`` prefill programs plus one exact-shape
program per tail length beyond the largest bucket, and exactly one
decode program.  This pass:

* sweeps the config space reachable from ``launch/serve.py`` flag
  domains (bucket tables × slots × mesh shapes, defaults parsed out of
  the argparse AST so flag changes are tracked),
* predicts the distinct abstract-signature set with the PRODUCTION
  bucketing code (``Engine._bucket_len`` on a shell instance — no
  parallel reimplementation that could drift),
* validates every predicted signature by abstract evaluation
  (``jax.eval_shape`` on ``lm.prefill``/``lm.decode_step`` with
  abstract params — no device, no compile), and
* emits RECOMPILE-BUDGET when the predicted distinct-signature count
  exceeds the documented budget.

It also AST-scans for jit-cache-key hazards: JIT-CLOSURE (a jitted
lambda/closure reading ``self.<attr>`` — baked at trace time, silently
stale after mutation; the repo convention is ``jax.jit(partial(f,
static...))`` with explicit bound args) and JIT-STATIC-UNHASHABLE
(list/dict/set literals passed in static argument positions).

``predict_prefill_shapes`` / ``budget_for`` are importable by tests so
the PR-4 jit-cache-bound test can assert the measured compile count
agrees with this static prediction.
"""

from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .common import Finding, Module, dotted_name, relpath, REPO_ROOT
from .rules import JIT_CLOSURE, JIT_STATIC_UNHASHABLE, RECOMPILE_BUDGET

LAUNCH_REL = "src/repro/launch/serve.py"
ENGINE_REL = "src/repro/serve/engine.py"


# ---------------------------------------------------------------------------
# static prediction (shared with tests)
# ---------------------------------------------------------------------------

def predict_prefill_shapes(buckets: Optional[Sequence[int]],
                           batch_slots: int,
                           lengths: Sequence[int]) -> Set[Tuple[int, int]]:
    """Distinct (rows, padded_len) admission signatures the Engine can
    compile for prompts of the given lengths, using the production
    bucketing code path (``Engine._bucket_len``).

    With buckets, every group admission pads to all ``batch_slots`` rows
    and the group max length rounds up to a bucket, so the signature for
    a group is ``(B, bucket_len(max lens))`` — and since the group max
    is itself one of the lengths, the set over singleton lengths covers
    every reachable group shape.  Without buckets shapes are exact and
    unbounded; callers get one signature per distinct length (solo
    admissions, rows=1) as a lower bound.
    """
    from repro.serve.engine import Engine

    if not buckets:
        return {(1, int(L)) for L in lengths}
    shell = Engine.__new__(Engine)           # no params/caches needed
    shell.buckets = tuple(sorted({int(b) for b in buckets}))
    return {(int(batch_slots), Engine._bucket_len(shell, int(L)))
            for L in lengths}


def budget_for(buckets: Optional[Sequence[int]], cache_len: int) -> int:
    """Documented admission-program budget: one program per bucket plus
    one exact-shape program per tail length beyond the largest bucket
    (DESIGN.md §12's 'rare tail')."""
    if not buckets:
        return int(cache_len)               # unbucketed: unbounded-ish
    bs = sorted({int(b) for b in buckets})
    tail = max(0, int(cache_len) - bs[-1])
    return len(bs) + tail


# ---------------------------------------------------------------------------
# launch flag-domain extraction (argparse AST)
# ---------------------------------------------------------------------------

def _flag_defaults(root: str) -> Dict[str, object]:
    """Pull add_argument defaults for the flags that shape the jit
    cache out of launch/serve.py without importing it."""
    path = os.path.join(root, LAUNCH_REL)
    out: Dict[str, object] = {"--slots": 4, "--cache-len": 256}
    try:
        tree = ast.parse(open(path, "r", encoding="utf-8").read())
    except OSError:
        return out
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"
                and node.args
                and isinstance(node.args[0], ast.Constant)):
            continue
        flag = node.args[0].value
        if flag not in ("--slots", "--cache-len"):
            continue
        for kw in node.keywords:
            if kw.arg == "default" and isinstance(kw.value, ast.Constant):
                out[flag] = kw.value.value
    return out


# ---------------------------------------------------------------------------
# jit-cache-key hazard AST scan
# ---------------------------------------------------------------------------

def _is_jit_call(node: ast.Call) -> bool:
    name = dotted_name(node.func)
    return name is not None and (
        name == "jax.jit" or name.endswith(".jit") and "jax" in name
        or name == "jit")


def _self_attr_reads(node: ast.AST) -> List[Tuple[str, int]]:
    out = []
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Attribute)
                and isinstance(sub.value, ast.Name)
                and sub.value.id == "self"):
            out.append((sub.attr, sub.lineno))
    return out


def _scan_hazards(mod: Module) -> List[Finding]:
    findings: List[Finding] = []
    # jitted names with static arg positions, for the unhashable check
    static_sites: Dict[str, Tuple[Set[int], Set[str]]] = {}

    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and _is_jit_call(node)):
            continue
        if not node.args:
            continue
        wrapped = node.args[0]
        # JIT-CLOSURE: jitted lambda/inline def reading self state.
        # partial(self._method, cfg, ...) is the sanctioned pattern —
        # bound args are explicit and hashable.
        target = wrapped
        if (isinstance(wrapped, ast.Call)
                and (dotted_name(wrapped.func) or "").endswith("partial")):
            target = None                   # explicit bound args: fine
        if isinstance(target, ast.Lambda):
            for attr, line in _self_attr_reads(target.body):
                findings.append(Finding(
                    JIT_CLOSURE, mod.rel, line,
                    "jitted lambda captures self.%s — the value is "
                    "baked at trace time; pass it as an argument or "
                    "partial(...) bound arg" % attr))
        # record static argument declarations
        nums: Set[int] = set()
        names: Set[str] = set()
        for kw in node.keywords:
            if kw.arg == "static_argnums":
                for c in ast.walk(kw.value):
                    if isinstance(c, ast.Constant) and isinstance(
                            c.value, int):
                        nums.add(c.value)
            elif kw.arg == "static_argnames":
                for c in ast.walk(kw.value):
                    if isinstance(c, ast.Constant) and isinstance(
                            c.value, str):
                        names.add(c.value)
        if nums or names:
            # find the name the jitted function is bound to:
            #   g = jax.jit(f, static_argnums=...)
            parent_name = _assigned_name(mod.tree, node)
            if parent_name:
                static_sites[parent_name] = (nums, names)

    # JIT-STATIC-UNHASHABLE: calls passing mutable literals in static
    # positions
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if name is None:
            continue
        short = name.split(".")[-1]
        if short not in static_sites:
            continue
        nums, names = static_sites[short]
        for i, arg in enumerate(node.args):
            if i in nums and isinstance(
                    arg, (ast.List, ast.Dict, ast.Set)):
                findings.append(Finding(
                    JIT_STATIC_UNHASHABLE, mod.rel, arg.lineno,
                    "unhashable %s literal in static arg %d of "
                    "jitted %r" % (type(arg).__name__.lower(), i, short)))
        for kw in node.keywords:
            if kw.arg in names and isinstance(
                    kw.value, (ast.List, ast.Dict, ast.Set)):
                findings.append(Finding(
                    JIT_STATIC_UNHASHABLE, mod.rel, kw.value.lineno,
                    "unhashable %s literal in static arg %r of "
                    "jitted %r" % (type(kw.value).__name__.lower(),
                                   kw.arg, short)))
    return findings


def _assigned_name(tree: ast.AST, call: ast.Call) -> Optional[str]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and node.value is call:
            t = node.targets[0]
            if isinstance(t, ast.Name):
                return t.id
            if isinstance(t, ast.Attribute):
                return t.attr
    return None


# ---------------------------------------------------------------------------
# pass driver
# ---------------------------------------------------------------------------

def run(root: str = REPO_ROOT,
        files: Optional[Sequence[str]] = None) -> List[Finding]:
    findings: List[Finding] = []

    # ---- AST hazards over the serving layer -------------------------------
    from .common import iter_py_files
    scan = files if files is not None else iter_py_files(
        root, (os.path.join("src", "repro"),))
    for path in scan:
        try:
            mod = Module(path, root)
        except SyntaxError:
            continue
        findings.extend(_scan_hazards(mod))
    if files is not None:
        return findings

    # ---- abstract-signature sweep -----------------------------------------
    import dataclasses
    from functools import partial

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.launch.serve import parse_buckets
    from repro.models import lm

    defaults = _flag_defaults(root)
    cache_len_flag = int(defaults["--cache-len"])
    slots_flag = int(defaults["--slots"])
    launch_line = 1

    # flag domains: bucket specs a user can pass × slots × mesh shapes.
    bucket_specs = ("4", "2", "32,64,128", None)
    slot_domain = (1, slots_flag)
    mesh_domain = ((1, 1), (2, 1), (1, 2))

    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=64,
                  vocab=64)
    aparams = jax.eval_shape(
        lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
    eval_cache_len = 64

    def traceable(B: int, S: int) -> Optional[str]:
        toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
        poss = jax.ShapeDtypeStruct((B, S), jnp.int32)
        try:
            jax.eval_shape(
                lambda p, t, po: lm.prefill(
                    p, cfg, t, positions=po,
                    cache_len=eval_cache_len),
                aparams, toks, poss)
            return None
        except Exception as e:          # abstract eval failed: report
            return "%s: %s" % (type(e).__name__, e)

    checked: Set[Tuple[int, int]] = set()
    for spec in bucket_specs:
        buckets = parse_buckets(spec, cache_len_flag)
        lengths = range(1, cache_len_flag + 1)
        budget = budget_for(buckets, cache_len_flag)
        for slots in slot_domain:
            shapes = predict_prefill_shapes(buckets, slots, lengths)
            for dp, tp in mesh_domain:
                # shapes are mesh-invariant by construction; the budget
                # must hold at every mesh point (rank_bucket_tables
                # gives every DP rank the same table).
                if buckets and len(shapes) > budget:
                    findings.append(Finding(
                        RECOMPILE_BUDGET, LAUNCH_REL, launch_line,
                        "--buckets %s --slots %d --mesh %d,%d: %d "
                        "distinct admission signatures > budget %d"
                        % (spec, slots, dp, tp, len(shapes), budget)))
                    break
        # abstract-eval a bounded sample of the predicted signatures
        # (bucketed tables are small; tail/exact shapes are sampled)
        sample = sorted(predict_prefill_shapes(
            buckets, slots_flag, lengths))[:8]
        for B, S in sample:
            if (B, min(S, eval_cache_len)) in checked:
                continue
            S = min(S, eval_cache_len)
            checked.add((B, S))
            err = traceable(B, S)
            if err:
                findings.append(Finding(
                    RECOMPILE_BUDGET, ENGINE_REL, 1,
                    "admission signature (%d, %d) fails abstract "
                    "evaluation: %s" % (B, S, err)))

    # decode: exactly one signature per batch size
    try:
        caches = jax.eval_shape(
            lambda p: lm.init_caches(p, cfg, slots_flag,
                                     eval_cache_len), aparams)
        jax.eval_shape(
            lambda p, t, po, c: lm.decode_step(p, cfg, t, po, c),
            aparams,
            jax.ShapeDtypeStruct((slots_flag, 1), jnp.int32),
            jax.ShapeDtypeStruct((slots_flag,), jnp.int32), caches)
    except Exception as e:
        findings.append(Finding(
            RECOMPILE_BUDGET, ENGINE_REL, 1,
            "decode signature fails abstract evaluation: %s: %s"
            % (type(e).__name__, e)))
    return findings
