"""Pass 5 — packed-format invariant checker.

Device-free validation of the serving containers built by
``core.deploy`` (format spec: ``core/sparse.py`` docstrings and
DESIGN.md §9–§10):

* ``PackedSASPWeight``: kn int32 (k, n) visit lists sorted n-major,
  every output-column block visited, dup-last-visit nnz padding
  zero-valued, shard-local coordinates in range, shard_kind/act/bias
  consistency, no double-counted (nonzero) visit within or across
  shards.
* ``PackedFFN``: jv int32 global d_ff block indices, live prefix
  strictly increasing with a ``-1`` zero-``w2v`` padding suffix,
  contiguous shard partitioning with no duplicated live visit, whole
  (unsharded) b2.

The validators run on concrete containers with plain numpy (no jit, no
accelerator) so tests and load-time checks can call them directly:
``validate_packed_weight`` / ``validate_packed_ffn`` /
``validate_params_tree``.  The analyzer pass (:func:`run`) exercises
the ``core/deploy.py`` call sites: it builds a tiny pruned model,
deploys it at several (tp, quantize, fuse_ffn) points, reshards it, and
validates every container plus cross-deployment visit-count
conservation.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .common import Finding, REPO_ROOT
from .rules import PACK_CONSERVE, PACK_DTYPE, PACK_KIND, PACK_PAD

DEPLOY_REL = "src/repro/core/deploy.py"


# ---------------------------------------------------------------------------
# runtime validators (device-free; importable by tests and load paths)
# ---------------------------------------------------------------------------

def _flat_lists(arr: np.ndarray, list_ndim: int) -> np.ndarray:
    """Collapse any leading (layer/shard) axes: (..., *list_dims) ->
    (prod(leading), *list_dims)."""
    lead = arr.shape[: arr.ndim - list_ndim]
    n = int(np.prod(lead, dtype=np.int64)) if lead else 1
    return arr.reshape((n,) + arr.shape[arr.ndim - list_ndim:])


def validate_packed_weight(pw, name: str = "weight") -> List[Tuple[str, str]]:
    """Validate one PackedSASPWeight. Returns [(rule_id, message)]."""
    errs: List[Tuple[str, str]] = []

    def err(rule: str, msg: str) -> None:
        errs.append((rule, "%s: %s" % (name, msg)))

    vals = np.asarray(pw.vals)
    kn = np.asarray(pw.kn)
    K, N = pw.shape
    bk, bn = pw.block
    tp = int(pw.shards)

    # -- dtypes -------------------------------------------------------------
    if kn.dtype != np.int32:
        err(PACK_DTYPE, "kn block table dtype %s, want int32" % kn.dtype)
    if pw.scale is not None and np.asarray(pw.scale).dtype != np.float32:
        err(PACK_DTYPE, "scale dtype %s, want float32"
            % np.asarray(pw.scale).dtype)
    if pw.bias is not None and np.asarray(pw.bias).dtype != np.float32:
        err(PACK_DTYPE, "bias dtype %s, want float32"
            % np.asarray(pw.bias).dtype)

    # -- structural / shard-kind consistency --------------------------------
    if tp > 1 and pw.shard_kind not in ("col", "row"):
        err(PACK_KIND, "shards=%d but shard_kind=%r (want 'col'/'row')"
            % (tp, pw.shard_kind))
        return errs
    if tp == 1 and pw.shard_kind is not None:
        err(PACK_KIND, "shards=1 but shard_kind=%r (want None)"
            % (pw.shard_kind,))
    if tp > 1 and pw.shard_kind == "row" and pw.act is not None:
        err(PACK_KIND, "row-sharded container carries act=%r "
            "(nonlinear epilogue on partial sums)" % (pw.act,))
    if vals.ndim != kn.ndim + 1:
        err(PACK_KIND, "vals ndim %d inconsistent with kn ndim %d"
            % (vals.ndim, kn.ndim))
        return errs
    if vals.shape[-2:] != (bk, bn):
        err(PACK_KIND, "vals block dims %s != declared block %s"
            % (vals.shape[-2:], (bk, bn)))
        return errs
    if kn.shape[-2] != 2 or kn.shape[-1] != vals.shape[-3]:
        err(PACK_KIND, "kn shape %s inconsistent with vals %s"
            % (kn.shape, vals.shape))
        return errs
    if tp > 1 and (vals.ndim < 4 or vals.shape[-4] != tp):
        err(PACK_KIND, "shards=%d but vals shard axis is %s"
            % (tp, vals.shape))
        return errs
    if pw.bias is not None:
        b = np.asarray(pw.bias)
        if tp > 1 and pw.shard_kind == "col":
            if b.shape[-2:] != (tp, N // tp):
                err(PACK_KIND, "col-sharded bias shape %s, want "
                    "(..., %d, %d)" % (b.shape, tp, N // tp))
        elif b.shape[-1] != N:
            err(PACK_KIND, "bias shape %s, want (..., %d)" % (b.shape, N))

    # -- per-(layer, shard) visit lists -------------------------------------
    KB, NB = K // bk, N // bn
    if tp > 1 and pw.shard_kind == "col":
        KB_l, NB_l = KB, NB // tp
    elif tp > 1:
        KB_l, NB_l = KB // tp, NB
    else:
        KB_l, NB_l = KB, NB

    flat_kn = _flat_lists(kn, 2)            # (G, 2, nnz)
    flat_v = _flat_lists(vals, 3)           # (G, nnz, bk, bn)
    n_lists = flat_kn.shape[0]
    shard_of = (lambda g: g % tp) if tp > 1 else (lambda g: 0)

    live_global: Dict[int, set] = {}
    for g in range(n_lists):
        ks, ns = flat_kn[g, 0], flat_kn[g, 1]
        nonzero = np.any(flat_v[g] != 0, axis=(1, 2))
        where = "list %d" % g
        if ks.min(initial=0) < 0 or ks.max(initial=0) >= KB_l:
            err(PACK_PAD, "%s: k coords outside [0, %d)" % (where, KB_l))
            continue
        if ns.min(initial=0) < 0 or ns.max(initial=0) >= NB_l:
            err(PACK_PAD, "%s: n coords outside [0, %d)" % (where, NB_l))
            continue
        # n-major sort: (n, k) lexicographically non-decreasing
        key = ns.astype(np.int64) * (KB_l + 1) + ks
        if np.any(np.diff(key) < 0):
            err(PACK_PAD, "%s: visits not sorted n-major by (n, k)"
                % where)
        if set(np.unique(ns)) != set(range(NB_l)):
            err(PACK_PAD, "%s: output blocks without a visit "
                "(flush coverage broken)" % where)
        # dup-last-visit padding: a visit repeating its predecessor's
        # coords must be zero-valued
        dup = (np.diff(ks) == 0) & (np.diff(ns) == 0)
        bad_pad = dup & nonzero[1:]
        if np.any(bad_pad):
            err(PACK_PAD, "%s: duplicate-coordinate visit carries "
                "nonzero values (padding must be zero)" % where)
        # conservation within the list: each (k, n) contributes at most
        # one nonzero block
        pairs = key[nonzero]
        if len(pairs) != len(np.unique(pairs)):
            err(PACK_CONSERVE, "%s: (k, n) block double-counted within "
                "a visit list" % where)
        # global coordinates for cross-shard conservation
        s = shard_of(g)
        layer = g // tp if tp > 1 else g
        if tp > 1 and pw.shard_kind == "col":
            gk, gn = ks, ns + s * NB_l
        elif tp > 1:
            gk, gn = ks + s * KB_l, ns
        else:
            gk, gn = ks, ns
        gset = live_global.setdefault(layer, set())
        for k_, n_ in zip(gk[nonzero].tolist(), gn[nonzero].tolist()):
            if (k_, n_) in gset:
                err(PACK_CONSERVE, "layer %d: block (k=%d, n=%d) "
                    "appears nonzero in more than one shard"
                    % (layer, k_, n_))
            gset.add((k_, n_))
    return errs


def live_visit_sets(pw) -> Dict[int, set]:
    """Per-layer set of GLOBAL (k, n) coordinates of nonzero visits —
    the conserved quantity across shardings of the same weight."""
    vals = np.asarray(pw.vals)
    kn = np.asarray(pw.kn)
    tp = int(pw.shards)
    K, N = pw.shape
    bk, bn = pw.block
    KB, NB = K // bk, N // bn
    flat_kn = _flat_lists(kn, 2)
    flat_v = _flat_lists(vals, 3)
    out: Dict[int, set] = {}
    for g in range(flat_kn.shape[0]):
        s = g % tp if tp > 1 else 0
        layer = g // tp if tp > 1 else g
        ks, ns = flat_kn[g, 0].copy(), flat_kn[g, 1].copy()
        if tp > 1 and pw.shard_kind == "col":
            ns = ns + s * (NB // tp)
        elif tp > 1:
            ks = ks + s * (KB // tp)
        nonzero = np.any(flat_v[g] != 0, axis=(1, 2))
        out.setdefault(layer, set()).update(
            zip(ks[nonzero].tolist(), ns[nonzero].tolist()))
    return out


def validate_packed_ffn(pf, name: str = "ffn") -> List[Tuple[str, str]]:
    """Validate one PackedFFN. Returns [(rule_id, message)]."""
    errs: List[Tuple[str, str]] = []

    def err(rule: str, msg: str) -> None:
        errs.append((rule, "%s: %s" % (name, msg)))

    w1v = np.asarray(pf.w1v)
    w2v = np.asarray(pf.w2v)
    tp = int(pf.shards)
    FB = pf.d_ff // pf.block_f

    if pf.jv is None:
        err(PACK_DTYPE, "jv global-visit-index table missing")
        return errs
    jv = np.asarray(pf.jv)
    if jv.dtype != np.int32:
        err(PACK_DTYPE, "jv dtype %s, want int32" % jv.dtype)
    for sname in ("s1", "s3", "s2"):
        s = getattr(pf, sname)
        if s is not None and np.asarray(s).dtype != np.float32:
            err(PACK_DTYPE, "%s dtype %s, want float32"
                % (sname, np.asarray(s).dtype))

    has_shard = tp > 1
    layer_axes = w1v.ndim - 3 - (1 if has_shard else 0)
    if layer_axes not in (0, 1):
        err(PACK_KIND, "w1v ndim %d inconsistent with shards=%d"
            % (w1v.ndim, tp))
        return errs
    if has_shard and w1v.shape[layer_axes] != tp:
        err(PACK_KIND, "shards=%d but w1v shard axis is %s"
            % (tp, w1v.shape))
        return errs
    b2 = np.asarray(pf.b2)
    if b2.ndim != layer_axes + 1 or b2.shape[-1] != pf.d_model:
        err(PACK_KIND, "b2 shape %s, want whole (unsharded) "
            "(..., %d) added once after the reduction"
            % (b2.shape, pf.d_model))
    if jv.shape != w1v.shape[:-2]:
        err(PACK_KIND, "jv shape %s inconsistent with w1v %s"
            % (jv.shape, w1v.shape))
        return errs

    flat_jv = _flat_lists(jv, 1)            # (G, nv)
    flat_w2 = _flat_lists(w2v, 3)           # (G, nv, bf, d)
    for g in range(flat_jv.shape[0]):
        j = flat_jv[g]
        where = "list %d" % g
        if j.min(initial=-1) < -1 or j.max(initial=-1) >= FB:
            err(PACK_PAD, "%s: jv outside [-1, %d)" % (where, FB))
            continue
        live = j >= 0
        # -1 entries are padding and must form a suffix
        if not live.all():
            first_pad = int(np.argmax(~live))
            if np.any(live[first_pad:]):
                err(PACK_PAD, "%s: live visit after jv=-1 padding"
                    % where)
        lj = j[live]
        if np.any(np.diff(lj) <= 0):
            err(PACK_PAD, "%s: live jv not strictly increasing"
                % where)
        pad_nonzero = np.any(flat_w2[g][~live] != 0)
        if pad_nonzero:
            err(PACK_PAD, "%s: jv=-1 padding visit has nonzero w2v "
                "(would contribute to the output)" % where)
        if has_shard:
            s = g % tp
            fs = FB // tp
            if lj.size and (lj.min() < s * fs or lj.max() >= (s + 1) * fs):
                err(PACK_CONSERVE, "%s: shard %d carries d_ff blocks "
                    "outside its contiguous range [%d, %d)"
                    % (where, s, s * fs, (s + 1) * fs))
    # cross-shard conservation: a d_ff block visited by 2 shards would
    # be down-projected twice
    if has_shard:
        n_layers = flat_jv.shape[0] // tp
        for layer in range(n_layers):
            seen: set = set()
            for s in range(tp):
                for v in flat_jv[layer * tp + s]:
                    if v < 0:
                        continue
                    if v in seen:
                        err(PACK_CONSERVE, "layer %d: d_ff block %d "
                            "visited by more than one shard"
                            % (layer, int(v)))
                    seen.add(int(v))
    return errs


def live_ffn_sets(pf) -> Dict[int, set]:
    """Per-layer set of live global d_ff block indices."""
    jv = np.asarray(pf.jv)
    tp = int(pf.shards)
    flat = _flat_lists(jv, 1)
    out: Dict[int, set] = {}
    for g in range(flat.shape[0]):
        layer = g // tp if tp > 1 else g
        j = flat[g]
        out.setdefault(layer, set()).update(
            int(v) for v in j[j >= 0].tolist())
    return out


def validate_params_tree(params) -> List[Tuple[str, str, str]]:
    """Walk a deployed param tree; validate every packed container.
    Returns [(keypath, rule_id, message)]."""
    import jax
    from repro.core.sparse import PackedFFN, PackedSASPWeight

    is_packed = lambda x: isinstance(x, (PackedSASPWeight, PackedFFN))
    leaves = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=is_packed)[0]
    out: List[Tuple[str, str, str]] = []
    for path, leaf in leaves:
        if not is_packed(leaf):
            continue
        key = jax.tree_util.keystr(path)
        if isinstance(leaf, PackedSASPWeight):
            errs = validate_packed_weight(leaf, name=key)
        else:
            errs = validate_packed_ffn(leaf, name=key)
        out.extend((key, rule, msg) for rule, msg in errs)
    return out


# ---------------------------------------------------------------------------
# analyzer pass: exercise core/deploy.py call sites
# ---------------------------------------------------------------------------

def _deploy_line(root: str, pattern: str = "def deploy_packed") -> int:
    path = os.path.join(root, DEPLOY_REL)
    try:
        with open(path, "r", encoding="utf-8") as fh:
            for i, line in enumerate(fh, 1):
                if line.startswith(pattern):
                    return i
    except OSError:
        pass
    return 1


def run(root: str = REPO_ROOT) -> List[Finding]:
    import dataclasses

    from repro.configs import SASPConfig, get_config, reduced
    from repro.core.deploy import deploy_packed, reshard_packed
    from repro.core.pruning import prune_params
    from repro.core.sparse import PackedFFN, PackedSASPWeight
    from repro.models import lm
    import jax

    line = _deploy_line(root)
    findings: List[Finding] = []

    def emit(rule: str, msg: str) -> None:
        findings.append(Finding(rule, DEPLOY_REL, line, msg))

    sasp = SASPConfig(enabled=True, block_k=16, block_n=16,
                      sparsity=0.5, scope="all")
    cfg = dataclasses.replace(
        reduced(get_config("qwen3-32b"), layers=2, d_model=64, vocab=64),
        sasp=sasp)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    pruned, _ = prune_params(params, sasp)

    deploys = {
        "tp=1": deploy_packed(pruned, cfg, fuse_ffn=True)[0],
        "tp=2": deploy_packed(pruned, cfg, fuse_ffn=True, tp=2)[0],
        "tp=2,unfused": deploy_packed(pruned, cfg, fuse_ffn=False,
                                      tp=2)[0],
        "tp=1,int8": deploy_packed(pruned, cfg, quantize=True)[0],
    }
    deploys["reshard 1->2"] = reshard_packed(deploys["tp=1"], cfg, tp=2)
    deploys["reshard 2->1"] = reshard_packed(deploys["tp=2"], cfg, tp=1)

    for tag, tree in deploys.items():
        for key, rule, msg in validate_params_tree(tree):
            emit(rule, "[deploy %s] %s %s" % (tag, key, msg))

    # cross-deployment visit-count conservation (fp32 deploys): the set
    # of live (k, n) / d_ff blocks per layer must be identical however
    # the schedule is sharded.
    def packed_by_key(tree):
        is_packed = lambda x: isinstance(
            x, (PackedSASPWeight, PackedFFN))
        out = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                tree, is_leaf=is_packed)[0]:
            if is_packed(leaf):
                out[jax.tree_util.keystr(path)] = leaf
        return out

    ref = packed_by_key(deploys["tp=1"])
    for tag in ("tp=2", "reshard 1->2", "reshard 2->1"):
        other = packed_by_key(deploys[tag])
        for key, leaf in ref.items():
            if key not in other:
                emit(PACK_CONSERVE, "[deploy %s] container %s missing "
                     "vs tp=1 deploy" % (tag, key))
                continue
            if isinstance(leaf, PackedSASPWeight):
                a, b = live_visit_sets(leaf), live_visit_sets(other[key])
            else:
                a, b = live_ffn_sets(leaf), live_ffn_sets(other[key])
            if a != b:
                lost = {k: sorted(v - b.get(k, set()))[:4]
                        for k, v in a.items() if v - b.get(k, set())}
                extra = {k: sorted(b.get(k, set()) - v)[:4]
                         for k, v in a.items() if b.get(k, set()) - v}
                emit(PACK_CONSERVE,
                     "[deploy %s] %s live visits not conserved vs tp=1 "
                     "(lost=%s extra=%s)" % (tag, key, lost, extra))
    return findings
