"""Shared infrastructure for the static analyzer: findings, baselines,
and an AST corpus over the repo's Python sources.

Nothing here imports JAX — passes that need abstract evaluation import
it lazily inside their ``run()``.
"""

from __future__ import annotations

import ast
import json
import os
from dataclasses import dataclass, asdict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .rules import RULES

REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", ".."))

# Directories never scanned as part of the repo corpus.  Fixture modules
# carry intentional violations for tests/test_analyze.py.
EXCLUDE_PARTS = (
    os.path.join("tests", "fixtures", "analyze"),
    os.path.join(".git", ""),
    "__pycache__",
)


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str            # repo-relative, forward slashes
    line: int
    message: str

    @property
    def severity(self) -> str:
        return RULES[self.rule].severity

    def key(self) -> Tuple[str, str, str]:
        # Baselines ignore line numbers so unrelated edits above a
        # baselined finding don't invalidate the baseline.
        return (self.rule, self.path, self.message)

    def render(self) -> str:
        return "%s:%d: %s [%s] %s" % (
            self.path, self.line, self.severity, self.rule, self.message)

    def to_json(self) -> Dict:
        d = asdict(self)
        d["severity"] = self.severity
        return d


def relpath(path: str, root: str = REPO_ROOT) -> str:
    return os.path.relpath(os.path.abspath(path), root).replace(os.sep, "/")


def iter_py_files(root: str = REPO_ROOT,
                  subdirs: Optional[Sequence[str]] = None) -> List[str]:
    """All .py files under ``root`` (or the given subdirs), excluding
    analyzer fixtures and caches.  Returns absolute paths, sorted."""
    bases = [os.path.join(root, s) for s in subdirs] if subdirs else [root]
    out: List[str] = []
    for base in bases:
        if os.path.isfile(base) and base.endswith(".py"):
            out.append(base)
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            # exclusion is relative to the scan root, so pointing run()
            # AT the fixture dir (tests/test_analyze.py) still works
            rel = os.path.relpath(dirpath, root)
            if any(part in rel for part in EXCLUDE_PARTS):
                continue
            for fn in filenames:
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return sorted(set(out))


class Module:
    """Parsed module with import-alias and symbol tables."""

    def __init__(self, path: str, root: str = REPO_ROOT):
        self.path = path
        self.rel = relpath(path, root)
        with open(path, "r", encoding="utf-8") as fh:
            self.source = fh.read()
        self.tree = ast.parse(self.source, filename=path)
        # local name -> dotted module path ("np" -> "numpy",
        # "dctx" -> "repro.distribution.context")
        self.import_alias: Dict[str, str] = {}
        # local name -> (module, symbol) for `from mod import sym [as x]`
        self.from_imports: Dict[str, Tuple[str, str]] = {}
        self.functions: Dict[str, ast.AST] = {}
        self.classes: Dict[str, Dict[str, ast.AST]] = {}
        self.name = self._module_name()
        self._index()

    def _module_name(self) -> str:
        rel = self.rel[:-3]  # strip .py
        parts = rel.split("/")
        if parts and parts[0] == "src":
            parts = parts[1:]
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    def _index(self) -> None:
        for node in self.tree.body:
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        self.import_alias[a.asname] = a.name
                    else:
                        top = a.name.split(".")[0]
                        self.import_alias[top] = top
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if node.level:  # relative import -> resolve against self
                    base = self.name.split(".")[: -node.level]
                    mod = ".".join(base + ([mod] if mod else []))
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.from_imports[a.asname or a.name] = (mod, a.name)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions[node.name] = node
            elif isinstance(node, ast.ClassDef):
                methods: Dict[str, ast.AST] = {}
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        methods[sub.name] = sub
                self.classes[node.name] = methods


class Corpus:
    """All modules under src/ (plus any extra files), indexed by module
    name, for cross-module call resolution."""

    def __init__(self, root: str = REPO_ROOT,
                 subdirs: Sequence[str] = ("src",)):
        self.root = root
        self.modules: Dict[str, Module] = {}
        for path in iter_py_files(root, subdirs):
            try:
                m = Module(path, root)
            except SyntaxError:
                continue
            self.modules[m.name] = m

    def module_of(self, name: str) -> Optional[Module]:
        return self.modules.get(name)

    def resolve_function(self, mod: Module, name: str):
        """Resolve a bare name in ``mod`` to (owning Module, func node),
        following `from x import f` chains.  Returns None if not a
        corpus-level function."""
        if name in mod.functions:
            return mod, mod.functions[name]
        if name in mod.from_imports:
            src_mod_name, sym = mod.from_imports[name]
            src = self.modules.get(src_mod_name)
            if src is not None and sym in src.functions:
                return src, src.functions[sym]
        return None

    def resolve_attr_function(self, mod: Module, obj: str, attr: str):
        """Resolve ``obj.attr(...)`` where obj is an imported module
        alias."""
        target = mod.import_alias.get(obj)
        if target is None and obj in mod.from_imports:
            # `from repro.models import lm` -> from_imports["lm"] =
            # ("repro.models", "lm"); the symbol may itself be a module.
            src_mod, sym = mod.from_imports[obj]
            target = src_mod + "." + sym
        if target is None:
            return None
        src = self.modules.get(target)
        if src is not None and attr in src.functions:
            return src, src.functions[attr]
        return None


def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ---- baseline -------------------------------------------------------------

def load_baseline(path: str) -> List[Tuple[str, str, str]]:
    if not os.path.exists(path):
        return []
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    return [(e["rule"], e["path"], e["message"])
            for e in data.get("findings", [])]


def write_baseline(path: str, findings: Iterable[Finding]) -> None:
    data = {"findings": [
        {"rule": f.rule, "path": f.path, "message": f.message}
        for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule))]}
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)
        fh.write("\n")
