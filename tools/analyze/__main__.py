"""CLI driver: ``python -m tools.analyze [--strict] [--baseline FILE]``.

Exit codes: 0 = no non-baselined error findings (or not --strict),
1 = strict mode with non-baselined errors, 2 = internal pass failure.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from . import PASS_NAMES, run_all
from .common import REPO_ROOT, load_baseline, write_baseline


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analyze",
        description="Repo-specific static analysis (DESIGN.md §15)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any non-baselined error finding")
    ap.add_argument("--baseline",
                    default=os.path.join(REPO_ROOT, "tools", "analyze",
                                         "baseline.json"),
                    help="baseline file of accepted findings")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline with current findings")
    ap.add_argument("--passes", default=None,
                    help="comma-separated subset of: %s"
                    % ",".join(PASS_NAMES))
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON on stdout")
    args = ap.parse_args(argv)

    passes = None
    if args.passes:
        passes = tuple(p.strip() for p in args.passes.split(",") if
                       p.strip())
        unknown = set(passes) - set(PASS_NAMES)
        if unknown:
            ap.error("unknown pass(es): %s" % ", ".join(sorted(unknown)))

    # Keep abstract evaluation off any accelerator and quiet.
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    src = os.path.join(REPO_ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)

    try:
        findings = run_all(passes=passes)
    except Exception as e:               # a broken pass must not pass CI
        print("analyzer internal error: %s: %s"
              % (type(e).__name__, e), file=sys.stderr)
        return 2

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print("wrote %d finding(s) to %s"
              % (len(findings), args.baseline))
        return 0

    baseline = set(load_baseline(args.baseline))
    fresh = [f for f in findings if f.key() not in baseline]
    stale = baseline - {f.key() for f in findings}

    if args.json:
        print(json.dumps({
            "findings": [f.to_json() for f in findings],
            "fresh": [f.to_json() for f in fresh],
        }, indent=2))
    else:
        for f in sorted(findings, key=lambda f: (f.path, f.line)):
            mark = "" if f.key() in baseline else " [NEW]"
            print(f.render() + mark)
        print("%d finding(s), %d new, %d baselined, %d stale baseline "
              "entr%s" % (len(findings), len(fresh),
                          len(findings) - len(fresh), len(stale),
                          "y" if len(stale) == 1 else "ies"))

    if args.strict and any(f.severity == "error" for f in fresh):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
