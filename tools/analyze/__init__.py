"""Repo-specific static analysis suite (DESIGN.md §15).

Six passes over the serving stack's implicit contracts:

1. ``trace_safety`` — host/trace confusion reachable from jax.jit roots
2. ``shim``         — shard_map must route through distribution/context
3. ``recompile``    — admission jit-cache budget + cache-key hazards
4. ``concurrency``  — declared lock-protection map for the frontend
5. ``packed``       — PackedSASPWeight/PackedFFN format invariants
6. ``telemetry``    — stats keys must be declared in DECLARED_STATS

Run ``python -m tools.analyze [--strict] [--baseline FILE]``.

Also home to :mod:`tools.analyze.pages` — a device-free runtime
invariant helper (``check_page_refcounts``) for the refcounted paged-KV
allocator (DESIGN.md §16); it validates live objects, so it is called
from tests/chaos harnesses rather than registered as a pass.
"""

from .rules import RULES, Rule, rules_for_pass, PASS_NAMES
from .common import Finding, load_baseline, write_baseline
from .pages import check_page_refcounts

__all__ = [
    "RULES", "Rule", "Finding", "PASS_NAMES", "rules_for_pass",
    "load_baseline", "write_baseline", "run_all",
    "check_page_refcounts",
]


def run_all(root=None, passes=None):
    """Run the requested passes (default: all). Returns findings."""
    from . import (concurrency, packed, recompile, shim,
                   telemetry, trace_safety)
    from .common import REPO_ROOT

    mods = {
        "trace_safety": trace_safety,
        "shim": shim,
        "recompile": recompile,
        "concurrency": concurrency,
        "packed": packed,
        "telemetry": telemetry,
    }
    root = root or REPO_ROOT
    out = []
    for name in (passes or PASS_NAMES):
        out.extend(mods[name].run(root))
    return out
