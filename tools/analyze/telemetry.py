"""Pass 6 — telemetry declaration discipline.

The serving stack's counters live behind ``Telemetry.engine_stats`` /
``CounterView`` (src/repro/serve/telemetry.py), which exports every
declared key to Prometheus and to the merged cluster summary.  A key
that is incremented but never declared in ``DECLARED_STATS`` silently
vanishes from the export surface — tests that read ``stats()`` still
pass while dashboards go blind.

Rule TELEMETRY-DECLARED: any write (``Assign``/``AugAssign``) to
``<obj>.stats[<string constant>]`` inside ``src/repro/serve/`` must use
a key present in ``repro.serve.telemetry.DECLARED_STATS``.

Dynamic (non-constant) keys are ignored — the registry API itself is
the escape hatch for those.
"""

from __future__ import annotations

import ast
import os
import sys
from typing import FrozenSet, List, Optional, Sequence

from .common import Finding, Module, iter_py_files, relpath, REPO_ROOT
from .rules import TELEMETRY_DECLARED

SCAN_SUBDIRS = (os.path.join("src", "repro", "serve"),)


def _declared_stats(root: str) -> FrozenSet[str]:
    """Import DECLARED_STATS from the repo under analysis.

    telemetry.py is deliberately JAX-free, so this stays cheap and safe
    to import from the analyzer (which must not pull in jax at module
    scope)."""
    src = os.path.join(root, "src")
    added = False
    if src not in sys.path:
        sys.path.insert(0, src)
        added = True
    try:
        from repro.serve.telemetry import DECLARED_STATS
        return frozenset(DECLARED_STATS)
    finally:
        if added:
            sys.path.remove(src)


def _stats_key(node: ast.AST) -> Optional[str]:
    """Return the string key for a ``<obj>.stats[<str const>]`` target."""
    if not isinstance(node, ast.Subscript):
        return None
    base = node.value
    if not (isinstance(base, ast.Attribute) and base.attr == "stats"):
        return None
    sl = node.slice
    if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
        return sl.value
    return None


def _check_module(mod: Module, declared: FrozenSet[str]) -> List[Finding]:
    out: List[Finding] = []

    def check(target: ast.AST) -> None:
        key = _stats_key(target)
        if key is not None and key not in declared:
            out.append(Finding(
                TELEMETRY_DECLARED, mod.rel,
                getattr(target, "lineno", 1),
                "stats key %r written but not declared in "
                "repro.serve.telemetry.DECLARED_STATS" % key))

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.AugAssign):
            check(node.target)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                check(t)
    return out


def run(root: str = REPO_ROOT,
        files: Optional[Sequence[str]] = None,
        declared: Optional[FrozenSet[str]] = None) -> List[Finding]:
    if declared is None:
        declared = _declared_stats(root if files is None else REPO_ROOT)
    if files is None:
        files = []
        for sub in SCAN_SUBDIRS:
            if os.path.isdir(os.path.join(root, sub)):
                files.extend(iter_py_files(root, (sub,)))
    findings: List[Finding] = []
    for path in files:
        try:
            mod = Module(path, root)
        except SyntaxError:
            continue
        findings.extend(_check_module(mod, declared))
    return findings
