"""Rule registry for the repo static analyzer.

Pure data — importable without JAX so that ``tools/check_docs.py`` can
cross-check the DESIGN.md §15 rule catalog without pulling in the
analysis passes (which import jax lazily inside ``run()``).

Severities: ``error`` findings fail ``--strict`` unless baselined;
``warning`` findings are printed but never fail the run.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class Rule:
    rule_id: str
    severity: str          # "error" | "warning"
    pass_name: str         # which pass emits it
    summary: str


# rule_id -> Rule.  The DESIGN.md §15 catalog must list exactly these ids
# (enforced by tools/check_docs.py::check_rule_catalog).
RULES: Dict[str, Rule] = {}


def _rule(rule_id: str, severity: str, pass_name: str, summary: str) -> str:
    RULES[rule_id] = Rule(rule_id, severity, pass_name, summary)
    return rule_id


# ---- pass 1: trace-safety -------------------------------------------------
TRACE_BRANCH = _rule(
    "TRACE-BRANCH", "error", "trace_safety",
    "Python-level branch (if/while/assert/ternary) on a traced value "
    "inside a jit-reachable function.")
TRACE_COERCE = _rule(
    "TRACE-COERCE", "error", "trace_safety",
    "Host coercion of a traced value (bool()/int()/float()/.item()/"
    ".tolist()) inside a jit-reachable function.")
TRACE_HOSTCALL = _rule(
    "TRACE-HOSTCALL", "error", "trace_safety",
    "Host callback (print/time.*/np.* on a tracer) inside a "
    "jit-reachable function.")

# ---- pass 2: shim enforcement --------------------------------------------
SHIM_IMPORT = _rule(
    "SHIM-IMPORT", "error", "shim",
    "Direct jax.experimental.shard_map / jax.shard_map import or "
    "attribute reference outside distribution/context.py.")

# ---- pass 3: recompile budget --------------------------------------------
RECOMPILE_BUDGET = _rule(
    "RECOMPILE-BUDGET", "error", "recompile",
    "Distinct abstract-signature count for prefill/decode/admission "
    "exceeds the documented budget for a launch flag configuration.")
JIT_CLOSURE = _rule(
    "JIT-CLOSURE", "error", "recompile",
    "jit-wrapped closure captures a mutable instance attribute "
    "(baked at trace time; silently stale after mutation).")
JIT_STATIC_UNHASHABLE = _rule(
    "JIT-STATIC-UNHASHABLE", "error", "recompile",
    "Call site passes an unhashable literal (list/dict/set) in a "
    "static argument position of a jitted function.")

# ---- pass 4: concurrency --------------------------------------------------
LOCK_UNHELD = _rule(
    "LOCK-UNHELD", "error", "concurrency",
    "Shared attribute read/written on a path that does not hold its "
    "declared owning lock.")
LOCK_ORDER = _rule(
    "LOCK-ORDER", "error", "concurrency",
    "Lock acquisition order contradicts the declared hierarchy "
    "(potential deadlock between threads).")

# ---- pass 5: packed-format invariants -------------------------------------
PACK_CONSERVE = _rule(
    "PACK-CONSERVE", "error", "packed",
    "Visit-count conservation violated: live visits lost, duplicated, "
    "or double-counted across shards / reshard round-trips.")
PACK_PAD = _rule(
    "PACK-PAD", "error", "packed",
    "nnz padding malformed: padding visits must be zero-valued "
    "dup-last-visit entries (PackedFFN: jv == -1) and visit lists "
    "must stay (n, k) n-major sorted with every output block visited.")
PACK_DTYPE = _rule(
    "PACK-DTYPE", "error", "packed",
    "Block-table (kn) or global-visit-index (jv) dtype is not int32, "
    "or scales/bias are not float32.")
PACK_KIND = _rule(
    "PACK-KIND", "error", "packed",
    "shard_kind inconsistency: shards>1 without col/row kind, row "
    "shard carrying a fused activation, or bias shape not matching "
    "the declared sharding.")

# ---- pass 6: telemetry declaration discipline ------------------------------
TELEMETRY_DECLARED = _rule(
    "TELEMETRY-DECLARED", "error", "telemetry",
    "stats[...] key written in src/repro/serve/ but not declared in "
    "repro.serve.telemetry.DECLARED_STATS (would be invisible to the "
    "Prometheus / cluster-summary export surface).")

PASS_NAMES: Tuple[str, ...] = (
    "trace_safety", "shim", "recompile", "concurrency", "packed",
    "telemetry")


def rules_for_pass(pass_name: str) -> Tuple[Rule, ...]:
    return tuple(r for r in RULES.values() if r.pass_name == pass_name)
