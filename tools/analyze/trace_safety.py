"""Pass 1 — trace-safety lint.

Flags host/trace confusion inside functions reachable from a
``jax.jit`` root in ``serve/engine.py``, ``models/`` or ``kernels/``:

* **TRACE-BRANCH** — Python-level control flow (``if``/``while``/
  ``assert``/ternary/comprehension guard) whose condition is a traced
  value.  Inside jit these raise ``TracerBoolConversionError`` at best
  and silently bake a trace-time constant at worst.
* **TRACE-COERCE** — host coercions of traced values: ``bool()``/
  ``int()``/``float()``/``range()``/``.item()``/``.tolist()``,
  ``not``/``and``/``or`` on tracers, ``math.*`` on tracers.
* **TRACE-HOSTCALL** — host callbacks on traced values (``np.*`` on a
  tracer concretizes; ``time.*`` runs once at trace time; ``print`` of
  a tracer is almost always a stale-debug bug — ``jax.debug.print`` is
  the sanctioned form and is whitelisted).

The analysis is a cross-module, per-parameter taint propagation to a
fixpoint: jit roots are discovered syntactically (``jax.jit(f)``,
``jax.jit(partial(f, static...))`` — partial's bound positionals are
compile-time constants, matching the repo convention — lambdas, and
``static_argnums``/``static_argnames``), the call graph follows
import aliases and ``self.`` method calls, and function values passed
to jax/pallas combinators (``scan``/``cond``/``pallas_call``/
``shard_map``/``pl.when``/…) are analyzed with all parameters traced.

Statically-derived values stay untainted: ``.shape``/``.ndim``/
``.dtype`` reads, packed-container static aux attributes, ``is None``
tests, ``in`` on static containers, and ``len()`` (legal on tracers —
returns a static dim).
"""

from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .common import Corpus, Finding, Module, dotted_name, REPO_ROOT
from .rules import TRACE_BRANCH, TRACE_COERCE, TRACE_HOSTCALL

# Directories whose jax.jit calls seed the reachability analysis.
ROOT_DIRS = ("src/repro/serve", "src/repro/models", "src/repro/kernels")

# Attribute reads that yield STATIC (host) values even on tracers /
# packed containers: array metadata + the containers' static aux fields.
STATIC_ATTRS = {
    "shape", "ndim", "dtype", "size", "itemsize",
    "block", "shards", "shard_kind", "act", "nnz", "nv", "k_max",
    "d_model", "d_ff", "block_f",
}

# jax/pallas combinators whose function-valued arguments run traced.
COMBINATOR_SUFFIXES = (
    "scan", "while_loop", "fori_loop", "cond", "switch", "vmap",
    "pmap", "map", "tree_map", "checkpoint", "remat", "pallas_call",
    "shard_map", "custom_vjp", "custom_jvp", "grad", "value_and_grad",
)

HOST_TIME_MODULES = ("time", "datetime")
COERCING_BUILTINS = {"bool", "int", "float", "complex", "range"}
TRACER_METHOD_COERCIONS = {"item", "tolist", "__bool__", "__int__",
                           "__float__"}


class FuncInfo:
    def __init__(self, module: Module, node: ast.AST,
                 cls: Optional[str] = None):
        self.module = module
        self.node = node
        self.cls = cls

    @property
    def label(self) -> str:
        n = getattr(self.node, "name", "<lambda>")
        return "%s%s" % (("%s." % self.cls) if self.cls else "", n)

    def params(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in getattr(a, "posonlyargs", [])]
        names += [p.arg for p in a.args]
        if self.is_method and names and names[0] == "self":
            pass  # kept; callers skip position 0
        return names

    @property
    def is_method(self) -> bool:
        return self.cls is not None and not self.is_static

    @property
    def is_static(self) -> bool:
        for d in getattr(self.node, "decorator_list", []):
            if isinstance(d, ast.Name) and d.id in ("staticmethod",
                                                    "classmethod"):
                return True
        return False


class _Analyzer:
    def __init__(self, corpus: Corpus):
        self.corpus = corpus
        self.findings: Dict[Tuple, Finding] = {}
        # id(node) -> (FuncInfo, set of tainted param names)
        self.state: Dict[int, Tuple[FuncInfo, Set[str]]] = {}
        self.queue: List[int] = []
        # return-taint memo: id(node) -> does the function return a
        # traced value even with every parameter tainted?
        self._ret_taint: Dict[int, bool] = {}
        self._ret_probing: Set[int] = set()
        self.probing = 0                # >0: suppress finding emission

    # -- worklist -----------------------------------------------------------

    def add_root(self, fi: FuncInfo, tainted: Set[str]) -> None:
        if self.probing:
            return                      # probes must not seed reachability
        key = id(fi.node)
        if key in self.state:
            prev = self.state[key][1]
            if tainted <= prev:
                return
            self.state[key] = (fi, prev | tainted)
        else:
            self.state[key] = (fi, set(tainted))
        if key not in self.queue:
            self.queue.append(key)

    def solve(self) -> List[Finding]:
        steps = 0
        while self.queue and steps < 10000:
            steps += 1
            key = self.queue.pop()
            fi, tainted = self.state[key]
            self._analyze(fi, set(tainted))
        return sorted(self.findings.values(),
                      key=lambda f: (f.path, f.line, f.rule))

    def emit(self, rule: str, mod: Module, line: int, msg: str) -> None:
        if self.probing:
            return
        f = Finding(rule, mod.rel, line, msg)
        self.findings[(f.rule, f.path, f.line, f.message)] = f

    def returns_tainted(self, fi: FuncInfo) -> bool:
        """Does ``fi`` return a traced value when all params are traced?
        Helper predicates over static config/dict structure return
        untainted results; call sites then stay branchable."""
        key = id(fi.node)
        if key in self._ret_taint:
            return self._ret_taint[key]
        if key in self._ret_probing:
            return True                 # recursion: conservative
        self._ret_probing.add(key)
        self.probing += 1
        try:
            params = fi.params()
            if fi.is_method and params and params[0] == "self":
                params = params[1:]
            walker = _BodyWalker(self, fi, set(params))
            if isinstance(fi.node, ast.Lambda):
                result = walker.expr(fi.node.body)
            else:
                walker.run(fi.node.body)
                result = walker.ret_tainted
        finally:
            self.probing -= 1
            self._ret_probing.discard(key)
        self._ret_taint[key] = result
        return result

    # -- function body analysis --------------------------------------------

    def _analyze(self, fi: FuncInfo, tainted_params: Set[str]) -> None:
        env = set(tainted_params)
        body = fi.node.body if not isinstance(fi.node, ast.Lambda) \
            else [ast.Expr(fi.node.body)]
        _BodyWalker(self, fi, env).run(body)

    # -- call resolution ----------------------------------------------------

    def resolve_call(self, fi: FuncInfo,
                     func: ast.AST) -> Optional[FuncInfo]:
        mod = fi.module
        if isinstance(func, ast.Name):
            r = self.corpus.resolve_function(mod, func.id)
            if r is not None:
                return FuncInfo(r[0], r[1])
            return None
        if isinstance(func, ast.Attribute):
            base = func.value
            if isinstance(base, ast.Name):
                if base.id == "self" and fi.cls:
                    methods = mod.classes.get(fi.cls, {})
                    if func.attr in methods:
                        return FuncInfo(mod, methods[func.attr], fi.cls)
                    return None
                r = self.corpus.resolve_attr_function(
                    mod, base.id, func.attr)
                if r is not None:
                    return FuncInfo(r[0], r[1])
        return None

    def propagate(self, callee: FuncInfo, pos_taints: List[bool],
                  kw_taints: Dict[str, bool],
                  skip_self: bool) -> None:
        params = callee.params()
        if skip_self and params and params[0] == "self":
            params = params[1:]
        tainted: Set[str] = set()
        for i, t in enumerate(pos_taints):
            if t and i < len(params):
                tainted.add(params[i])
        for name, t in kw_taints.items():
            if t and name in params:
                tainted.add(name)
        self.add_root(callee, tainted)

    def mark_all_tainted(self, callee: FuncInfo) -> None:
        params = callee.params()
        if callee.is_method and params and params[0] == "self":
            params = params[1:]
        self.add_root(callee, set(params))


class _BodyWalker:
    """Single-function abstract interpreter over taint."""

    def __init__(self, an: _Analyzer, fi: FuncInfo, env: Set[str]):
        self.an = an
        self.fi = fi
        self.mod = fi.module
        self.env = env
        self.local_funcs: Dict[str, ast.AST] = {}
        self.ret_tainted = False

    # ---- entry ------------------------------------------------------------

    def run(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            self.stmt(stmt)

    # ---- statements -------------------------------------------------------

    def stmt(self, node: ast.stmt) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.local_funcs[node.name] = node
            for dec in node.decorator_list:
                # @pl.when(traced): body runs traced in-place
                name = dotted_name(dec.func) if isinstance(
                    dec, ast.Call) else dotted_name(dec)
                if name and name.split(".")[-1] == "when":
                    self._analyze_nested(node, all_tainted=False)
            return
        if isinstance(node, ast.If):
            if self.expr(node.test):
                self.an.emit(TRACE_BRANCH, self.mod, node.lineno,
                             "%s: `if` on a traced value"
                             % self.fi.label)
            for b in node.body + node.orelse:
                self.stmt(b)
        elif isinstance(node, ast.While):
            if self.expr(node.test):
                self.an.emit(TRACE_BRANCH, self.mod, node.lineno,
                             "%s: `while` on a traced value"
                             % self.fi.label)
            for b in node.body + node.orelse:
                self.stmt(b)
        elif isinstance(node, ast.Assert):
            if self.expr(node.test):
                self.an.emit(TRACE_BRANCH, self.mod, node.lineno,
                             "%s: `assert` on a traced value"
                             % self.fi.label)
        elif isinstance(node, ast.For):
            it_tainted = self.expr(node.iter)
            self._bind_target(node.target, it_tainted, node.iter)
            for b in node.body + node.orelse:
                self.stmt(b)
        elif isinstance(node, ast.Assign):
            t = self.expr(node.value)
            for tgt in node.targets:
                self._bind_target(tgt, t, node.value)
        elif isinstance(node, ast.AnnAssign):
            if node.value is not None:
                self._bind_target(node.target, self.expr(node.value),
                                  node.value)
        elif isinstance(node, ast.AugAssign):
            t = self.expr(node.value)
            if isinstance(node.target, ast.Name):
                if t:
                    self.env.add(node.target.id)
                else:
                    self.expr(node.target)
        elif isinstance(node, ast.Return):
            if node.value is not None:
                self.ret_tainted |= self.expr(node.value)
        elif isinstance(node, ast.Expr):
            self.expr(node.value)
        elif isinstance(node, ast.With):
            for item in node.items:
                self.expr(item.context_expr)
            for b in node.body:
                self.stmt(b)
        elif isinstance(node, (ast.Try,)):
            for b in (node.body + node.orelse + node.finalbody):
                self.stmt(b)
            for h in node.handlers:
                for b in h.body:
                    self.stmt(b)
        elif isinstance(node, ast.Raise):
            if node.exc is not None:
                self.expr(node.exc)
        # Pass/Import/Global/Delete/etc: nothing traced

    def _bind_target(self, tgt: ast.AST, tainted: bool,
                     value: ast.AST) -> None:
        if isinstance(tgt, ast.Name):
            if tainted:
                self.env.add(tgt.id)
            else:
                self.env.discard(tgt.id)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            elts = tgt.elts
            # enumerate(x): index is static even when x is traced
            skip_first = (isinstance(value, ast.Call)
                          and isinstance(value.func, ast.Name)
                          and value.func.id == "enumerate")
            # zip(a, b, …) unpacked elementwise: taint per component
            if (isinstance(value, ast.Call)
                    and isinstance(value.func, ast.Name)
                    and value.func.id == "zip"
                    and len(value.args) == len(elts)):
                for e, a in zip(elts, value.args):
                    self._bind_target(e, self.expr(a), a)
                return
            for i, e in enumerate(elts):
                self._bind_target(e, tainted and not (
                    skip_first and i == 0), value)
        elif isinstance(tgt, (ast.Subscript, ast.Attribute, ast.Starred)):
            pass

    # ---- nested functions -------------------------------------------------

    def _analyze_nested(self, node: ast.AST,
                        all_tainted: bool) -> None:
        fi = FuncInfo(self.mod, node, self.fi.cls)
        params = fi.params()
        env = set(self.env)             # closure sees enclosing taint
        if all_tainted:
            env.update(params)
        walker = _BodyWalker(self.an, fi, env)
        walker.local_funcs = dict(self.local_funcs)
        if isinstance(node, ast.Lambda):
            walker.expr(node.body)
        else:
            walker.run(node.body)

    def _maybe_function_value(self, node: ast.AST) -> Optional[object]:
        """A function-valued expression: nested def / lambda / corpus
        function reference."""
        if isinstance(node, ast.Lambda):
            return node
        if isinstance(node, ast.Name):
            if node.id in self.local_funcs:
                return self.local_funcs[node.id]
            r = self.an.corpus.resolve_function(self.mod, node.id)
            if r is not None:
                return FuncInfo(r[0], r[1])
        if isinstance(node, ast.Attribute):
            fi = self.an.resolve_call(self.fi, node)
            if fi is not None:
                return fi
        if isinstance(node, ast.Call):
            # partial(f, ...) / checkpoint(f) passed as the callee
            name = dotted_name(node.func)
            if name and name.split(".")[-1] in (
                    "partial", "checkpoint", "remat"):
                return self._maybe_function_value(
                    node.args[0]) if node.args else None
        return None

    def _mark_function_value_tainted(self, val: object) -> None:
        if isinstance(val, FuncInfo):
            self.an.mark_all_tainted(val)
        elif isinstance(val, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            self._analyze_nested(val, all_tainted=True)

    # ---- expressions (return: tainted?) -----------------------------------

    def expr(self, node: ast.AST) -> bool:
        if node is None or isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.env
        if isinstance(node, ast.Attribute):
            base_t = self.expr(node.value)
            if node.attr in STATIC_ATTRS:
                return False
            return base_t
        if isinstance(node, ast.Subscript):
            self.expr(node.slice)
            return self.expr(node.value)
        if isinstance(node, ast.Call):
            return self.call(node)
        if isinstance(node, ast.BoolOp):
            ts = [self.expr(v) for v in node.values]
            # `a and b` bool-coerces every operand but the last
            for v, t in list(zip(node.values, ts))[:-1]:
                if t:
                    self.an.emit(
                        TRACE_COERCE, self.mod, node.lineno,
                        "%s: and/or bool-coerces a traced value (use "
                        "jnp.logical_and/or or jnp.where)"
                        % self.fi.label)
            return any(ts)
        if isinstance(node, ast.UnaryOp):
            t = self.expr(node.operand)
            if t and isinstance(node.op, ast.Not):
                self.an.emit(TRACE_COERCE, self.mod, node.lineno,
                             "%s: `not` bool-coerces a traced value "
                             "(use jnp.logical_not)" % self.fi.label)
            return t
        if isinstance(node, ast.BinOp):
            return self.expr(node.left) | self.expr(node.right)
        if isinstance(node, ast.Compare):
            ts = [self.expr(node.left)] + [self.expr(c)
                                           for c in node.comparators]
            if all(isinstance(op, (ast.Is, ast.IsNot, ast.In,
                                   ast.NotIn)) for op in node.ops):
                return False            # identity/containment: static
            return any(ts)
        if isinstance(node, ast.IfExp):
            if self.expr(node.test):
                self.an.emit(TRACE_BRANCH, self.mod, node.lineno,
                             "%s: ternary on a traced value (use "
                             "jnp.where / lax.cond)" % self.fi.label)
            return self.expr(node.body) | self.expr(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.Dict):
            return any(self.expr(v) for v in
                       list(node.keys) + list(node.values)
                       if v is not None)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            t = False
            for gen in node.generators:
                it = self.expr(gen.iter)
                self._bind_target(gen.target, it, gen.iter)
                t |= it
                for cond in gen.ifs:
                    if self.expr(cond):
                        self.an.emit(
                            TRACE_BRANCH, self.mod, node.lineno,
                            "%s: comprehension guard on a traced "
                            "value" % self.fi.label)
            if isinstance(node, ast.DictComp):
                t |= self.expr(node.key) | self.expr(node.value)
            else:
                t |= self.expr(node.elt)
            return t
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        if isinstance(node, ast.JoinedStr):
            for v in node.values:
                if isinstance(v, ast.FormattedValue):
                    self.expr(v.value)
            return False
        if isinstance(node, ast.Lambda):
            return False                # analyzed when invoked/passed
        if isinstance(node, (ast.Slice,)):
            for part in (node.lower, node.upper, node.step):
                if part is not None:
                    self.expr(part)
            return False
        return False

    # ---- calls ------------------------------------------------------------

    def call(self, node: ast.Call) -> bool:
        arg_ts = [self.expr(a) for a in node.args]
        kw_ts = {kw.arg: self.expr(kw.value) for kw in node.keywords
                 if kw.arg is not None}
        for kw in node.keywords:
            if kw.arg is None:
                self.expr(kw.value)
        any_tainted = any(arg_ts) or any(kw_ts.values())
        func = node.func
        name = dotted_name(func) or ""
        short = name.split(".")[-1]

        # builtin coercions -------------------------------------------------
        if isinstance(func, ast.Name):
            if func.id in COERCING_BUILTINS and any_tainted:
                self.an.emit(TRACE_COERCE, self.mod, node.lineno,
                             "%s: %s() concretizes a traced value"
                             % (self.fi.label, func.id))
                return False
            if func.id in ("len", "isinstance", "hasattr", "id",
                           "getattr", "repr", "str", "type", "print"):
                if func.id == "print" and any_tainted:
                    self.an.emit(
                        TRACE_HOSTCALL, self.mod, node.lineno,
                        "%s: print() of a traced value runs at trace "
                        "time only (use jax.debug.print)"
                        % self.fi.label)
                return False
            if func.id in ("min", "max", "sum", "abs", "sorted",
                           "zip", "enumerate", "tuple", "list",
                           "dict", "set", "reversed"):
                return any_tainted

        # method-style coercions on tracers --------------------------------
        if isinstance(func, ast.Attribute):
            base_t = self.expr(func.value)
            if base_t and func.attr in TRACER_METHOD_COERCIONS:
                self.an.emit(TRACE_COERCE, self.mod, node.lineno,
                             "%s: .%s() concretizes a traced value"
                             % (self.fi.label, func.attr))
                return False

        # module classification --------------------------------------------
        root_alias = name.split(".")[0] if name else None
        alias_target = self.mod.import_alias.get(root_alias or "", "")
        is_jax = alias_target.startswith("jax") or root_alias == "jax"
        is_np = alias_target in ("numpy",) or root_alias in ("np",)
        is_time = alias_target in HOST_TIME_MODULES \
            or root_alias in HOST_TIME_MODULES
        is_math = alias_target == "math" or root_alias == "math"

        if is_time:
            self.an.emit(TRACE_HOSTCALL, self.mod, node.lineno,
                         "%s: %s() runs on the host at trace time "
                         "(stale inside jit)" % (self.fi.label, name))
            return False
        if is_math and any_tainted:
            self.an.emit(TRACE_COERCE, self.mod, node.lineno,
                         "%s: math.%s concretizes a traced value "
                         "(use jnp)" % (self.fi.label, short))
            return False
        if is_np and any_tainted:
            self.an.emit(TRACE_HOSTCALL, self.mod, node.lineno,
                         "%s: numpy call %s on a traced value "
                         "concretizes it (use jnp)"
                         % (self.fi.label, name))
            return False

        # jax combinators: function-valued args run traced ------------------
        if (is_jax or short in ("pallas_call", "shard_map", "when")
                or name.startswith("pl.")):
            if short in COMBINATOR_SUFFIXES or short == "when":
                for a in list(node.args) + [kw.value
                                            for kw in node.keywords]:
                    val = self._maybe_function_value(a)
                    if val is not None:
                        self._mark_function_value_tainted(val)
            if name == "jax.eval_shape" or short == "eval_shape":
                return False
            if name.startswith("jax.debug"):
                return False
            return True                 # jnp/jax ops yield tracers

    # shim shard_map (corpus function): body runs traced --------------
        if short == "shard_map":
            for a in list(node.args) + [kw.value
                                        for kw in node.keywords]:
                val = self._maybe_function_value(a)
                if val is not None:
                    self._mark_function_value_tainted(val)
            return True

        # partial over a corpus/local function: propagate bound args -------
        if short == "partial" and node.args:
            val = self._maybe_function_value(node.args[0])
            if isinstance(val, FuncInfo):
                self.an.propagate(val, arg_ts[1:], kw_ts,
                                  skip_self=False)
            elif val is not None:
                self._analyze_nested(val, all_tainted=any_tainted)
            return False

        # local nested function call ---------------------------------------
        if isinstance(func, ast.Name) and func.id in self.local_funcs:
            sub = self.local_funcs[func.id]
            fi = FuncInfo(self.mod, sub, self.fi.cls)
            params = fi.params()
            env = set(self.env)
            for i, t in enumerate(arg_ts):
                if i < len(params):
                    (env.add if t else env.discard)(params[i])
            for k, t in kw_ts.items():
                if t:
                    env.add(k)
            walker = _BodyWalker(self.an, fi, env)
            walker.local_funcs = dict(self.local_funcs)
            if isinstance(sub, ast.Lambda):
                return walker.expr(sub.body)
            walker.run(sub.body)
            return walker.ret_tainted

        # corpus-resolved call: propagate per-parameter taint ---------------
        callee = self.an.resolve_call(self.fi, func)
        if callee is not None:
            skip_self = (isinstance(func, ast.Attribute)
                         and isinstance(func.value, ast.Name)
                         and func.value.id == "self"
                         and callee.is_method)
            self.an.propagate(callee, arg_ts, kw_ts, skip_self)
            return any_tainted and self.an.returns_tainted(callee)
        # unresolvable: conservatively taint-propagating, no flag
        return any_tainted


# ---------------------------------------------------------------------------
# jit-root discovery
# ---------------------------------------------------------------------------

def _is_jit(node: ast.Call) -> bool:
    name = dotted_name(node.func)
    return name in ("jax.jit", "jit") or (
        name is not None and name.endswith(".jit")
        and name.startswith("jax"))


class _RootFinder(ast.NodeVisitor):
    """Collect (jit call, enclosing class name) pairs."""

    def __init__(self):
        self.roots: List[Tuple[ast.Call, Optional[str]]] = []
        self._cls: List[str] = []

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._cls.append(node.name)
        self.generic_visit(node)
        self._cls.pop()

    def visit_Call(self, node: ast.Call) -> None:
        if _is_jit(node):
            self.roots.append(
                (node, self._cls[-1] if self._cls else None))
        self.generic_visit(node)


def _static_positions(call: ast.Call) -> Tuple[Set[int], Set[str]]:
    nums: Set[int] = set()
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(
                        c.value, int):
                    nums.add(c.value)
        elif kw.arg == "static_argnames":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(
                        c.value, str):
                    names.add(c.value)
    return nums, names


def _resolve_root_target(corpus: Corpus, mod: Module,
                         cls: Optional[str],
                         expr: ast.AST) -> Optional[FuncInfo]:
    if isinstance(expr, ast.Name):
        if cls and expr.id in mod.classes.get(cls, {}):
            return FuncInfo(mod, mod.classes[cls][expr.id], cls)
        r = corpus.resolve_function(mod, expr.id)
        if r is not None:
            return FuncInfo(r[0], r[1])
        return None
    if isinstance(expr, ast.Attribute):
        base = expr.value
        if isinstance(base, ast.Name):
            if base.id == "self" and cls:
                methods = mod.classes.get(cls, {})
                if expr.attr in methods:
                    return FuncInfo(mod, methods[expr.attr], cls)
                return None
            r = corpus.resolve_attr_function(mod, base.id, expr.attr)
            if r is not None:
                return FuncInfo(r[0], r[1])
    return None


def _seed_roots(an: _Analyzer, corpus: Corpus,
                root_dirs: Sequence[str]) -> int:
    n = 0
    for mod in corpus.modules.values():
        if not any(mod.rel.startswith(d) for d in root_dirs):
            continue
        rf = _RootFinder()
        rf.visit(mod.tree)
        for call, cls in rf.roots:
            if not call.args:
                continue
            wrapped = call.args[0]
            nums, names = _static_positions(call)
            n += 1
            if isinstance(wrapped, ast.Lambda):
                fi = FuncInfo(mod, wrapped, cls)
                params = [p.arg for p in wrapped.args.args]
                tainted = {p for i, p in enumerate(params)
                           if i not in nums and p not in names}
                an.add_root(fi, tainted)
                continue
            n_static = 0
            target = wrapped
            if (isinstance(wrapped, ast.Call)
                    and (dotted_name(wrapped.func) or "").split(".")[-1]
                    == "partial"):
                # jax.jit(partial(f, s1, s2, kw=...)): leading
                # positionals and keywords are compile-time constants
                n_static = len(wrapped.args) - 1
                names |= {kw.arg for kw in wrapped.keywords
                          if kw.arg is not None}
                target = wrapped.args[0] if wrapped.args else None
            if target is None:
                continue
            fi = _resolve_root_target(corpus, mod, cls, target)
            if fi is None:
                continue
            params = fi.params()
            if fi.is_method and params and params[0] == "self":
                params = params[1:]
            tainted = {p for i, p in enumerate(params)
                       if i >= n_static and i not in nums
                       and p not in names}
            an.add_root(fi, tainted)
    return n


def run(root: str = REPO_ROOT,
        subdirs: Sequence[str] = ("src",),
        root_dirs: Sequence[str] = ROOT_DIRS) -> List[Finding]:
    corpus = Corpus(root, subdirs)
    an = _Analyzer(corpus)
    _seed_roots(an, corpus, root_dirs)
    return an.solve()
