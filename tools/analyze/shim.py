"""Pass 2 — shim enforcement.

Every shard_map use must route through the version-portability shim in
``src/repro/distribution/context.py`` (it papers over the
``jax.experimental.shard_map``/``check_rep`` vs ``jax.shard_map``/
``check_vma`` API split).  This pass forbids, anywhere else in the
repo:

* ``import jax.experimental.shard_map`` (any form)
* ``from jax.experimental import shard_map`` / ``from
  jax.experimental.shard_map import ...``
* ``from jax import shard_map``
* attribute references ``jax.shard_map`` or
  ``jax.experimental.shard_map`` on an imported jax alias
"""

from __future__ import annotations

import ast
import os
from typing import List, Optional, Sequence

from .common import Finding, Module, iter_py_files, relpath, REPO_ROOT
from .rules import SHIM_IMPORT

ALLOWED = ("src/repro/distribution/context.py",)

# Directories worth scanning: everything that contains repo Python.
SCAN_SUBDIRS = ("src", "tests", "benchmarks", "examples", "launch", "tools")


def _check_module(mod: Module) -> List[Finding]:
    out: List[Finding] = []

    def bad(node: ast.AST, what: str) -> None:
        out.append(Finding(
            SHIM_IMPORT, mod.rel, getattr(node, "lineno", 1),
            "%s — route through repro.distribution.context.shard_map"
            % what))

    # Which local names alias the jax package (import jax [as j]).
    jax_aliases = {alias for alias, target in mod.import_alias.items()
                   if target == "jax" or target.startswith("jax.")}

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.startswith("jax.experimental.shard_map"):
                    bad(node, "direct import of jax.experimental.shard_map")
        elif isinstance(node, ast.ImportFrom):
            m = node.module or ""
            if m.startswith("jax.experimental.shard_map"):
                bad(node, "direct import from jax.experimental.shard_map")
            elif m == "jax.experimental":
                for a in node.names:
                    if a.name == "shard_map":
                        bad(node, "direct import of "
                            "jax.experimental.shard_map")
            elif m == "jax":
                for a in node.names:
                    if a.name == "shard_map":
                        bad(node, "direct import of jax.shard_map")
        elif isinstance(node, ast.Attribute) and node.attr == "shard_map":
            # jax.shard_map / jax.experimental.shard_map / j.shard_map
            base = node.value
            if isinstance(base, ast.Name) and base.id in jax_aliases:
                bad(node, "direct reference to jax.shard_map")
            elif (isinstance(base, ast.Attribute)
                  and base.attr == "experimental"
                  and isinstance(base.value, ast.Name)
                  and base.value.id in jax_aliases):
                bad(node, "direct reference to jax.experimental.shard_map")
    return out


def run(root: str = REPO_ROOT,
        files: Optional[Sequence[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    if files is None:
        files = []
        for sub in SCAN_SUBDIRS:
            if os.path.isdir(os.path.join(root, sub)):
                files.extend(iter_py_files(root, (sub,)))
    for path in files:
        rel = relpath(path, root)
        if rel in ALLOWED:
            continue
        try:
            mod = Module(path, root)
        except SyntaxError:
            continue
        findings.extend(_check_module(mod))
    return findings
