"""Docs-freshness checker (CI `docs` job; also tests/test_docs.py).

Three guarantees, all cheap and dependency-free:

1. **Section manifest** — the `## §N Title` headings of DESIGN.md must
   match `tools/docs_manifest.json` exactly (count, order, titles).
   Module docstrings cite sections by number, so silent renumbering is
   the docs-rot mode this catches: adding a section without updating
   the manifest (or vice versa) fails CI.
2. **Links and anchors** — every local markdown link in the files
   listed under `link_checked` must resolve: relative file targets
   exist, and `#anchor` fragments match a GitHub-slugified heading of
   the target document. External (http/https/mailto) links are not
   fetched.
3. **Analyzer rule catalog** — the DESIGN.md §15 table must list
   exactly the rule ids registered in `tools/analyze/rules.py` (pure
   data, no JAX import), so the documented catalog cannot drift from
   the analyzer.

Exit code 0 = fresh; 1 = stale, with one line per finding.
"""
from __future__ import annotations

import json
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MANIFEST = os.path.join(REPO, "tools", "docs_manifest.json")

HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*$", re.M)
SECTION_RE = re.compile(r"^##\s+(§\d+\s+.*?)\s*$", re.M)
# [text](target) — skips images' leading ! by matching the bracket pair
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_FENCE_RE = re.compile(r"^```.*?^```", re.M | re.S)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, spaces to hyphens, punctuation
    (other than hyphen/underscore) dropped."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s, flags=re.UNICODE)
    return s.replace(" ", "-")


def read(path: str) -> str:
    with open(path, encoding="utf-8") as f:
        return f.read()


def check_sections(manifest: dict) -> list:
    errs = []
    for fname, spec in manifest.items():
        if not isinstance(spec, dict) or "sections" not in spec:
            continue
        want = spec["sections"]
        got = SECTION_RE.findall(read(os.path.join(REPO, fname)))
        # normalize runs of whitespace (hard-wrapped titles)
        got = [re.sub(r"\s+", " ", g) for g in got]
        if len(got) != len(want):
            errs.append(f"{fname}: {len(got)} '## §N' sections, "
                        f"manifest lists {len(want)} — update "
                        f"tools/docs_manifest.json with the doc")
        for i, (g, w) in enumerate(zip(got, want)):
            if g != w:
                errs.append(f"{fname}: section {i + 1} is {g!r}, "
                            f"manifest says {w!r}")
    return errs


def check_links(manifest: dict) -> list:
    errs = []
    slugs = {}

    def slugs_of(path):
        if path not in slugs:
            slugs[path] = {github_slug(h)
                           for _, h in HEADING_RE.findall(read(path))}
        return slugs[path]

    for fname in manifest.get("link_checked", ()):
        fpath = os.path.join(REPO, fname)
        text = CODE_FENCE_RE.sub("", read(fpath))
        for target in LINK_RE.findall(text):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # external
                continue
            path_part, _, anchor = target.partition("#")
            if path_part:
                tpath = os.path.normpath(
                    os.path.join(os.path.dirname(fpath), path_part))
                if not os.path.exists(tpath):
                    errs.append(f"{fname}: dangling link {target!r} "
                                f"({path_part} does not exist)")
                    continue
            else:
                tpath = fpath
            if anchor and tpath.endswith(".md"):
                if anchor.lower() not in slugs_of(tpath):
                    errs.append(f"{fname}: anchor {target!r} matches no "
                                f"heading in {os.path.relpath(tpath, REPO)}")
    return errs


def check_rule_catalog() -> list:
    """DESIGN.md §15's rule table must list exactly the ids registered
    in tools/analyze/rules.py — no documented-but-unregistered rules,
    no registered-but-undocumented ones."""
    sys.path.insert(0, REPO)
    from tools.analyze.rules import RULES

    text = read(os.path.join(REPO, "DESIGN.md"))
    m = re.search(r"^## §15 .*?(?=^## §|\Z)", text, re.M | re.S)
    if m is None:
        return ["DESIGN.md: no '## §15' section for the analyzer "
                "rule catalog"]
    # table rows: | `RULE-ID` | pass | ... |
    documented = set(re.findall(r"^\|\s*`([A-Z][A-Z-]+)`\s*\|",
                                m.group(0), re.M))
    registered = set(RULES)
    errs = []
    for rid in sorted(registered - documented):
        errs.append(f"DESIGN.md §15: registered rule {rid} missing "
                    f"from the catalog table")
    for rid in sorted(documented - registered):
        errs.append(f"DESIGN.md §15: catalog lists {rid}, which is not "
                    f"registered in tools/analyze/rules.py")
    return errs


def main() -> int:
    with open(MANIFEST, encoding="utf-8") as f:
        manifest = json.load(f)
    errs = (check_sections(manifest) + check_links(manifest)
            + check_rule_catalog())
    for e in errs:
        print(f"docs-freshness: {e}")
    if not errs:
        print("docs-freshness: OK")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
