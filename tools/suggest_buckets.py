"""Fit a prefill bucket table to an observed prompt-length histogram.

First half of ROADMAP's *continuous bucket tuning*: the serving
scheduler records every submitted prompt's length
(``ShardedScheduler.prompt_length_histogram()``); this tool fits a
bucket table to that histogram by exact dynamic programming, minimizing
the expected number of PAD tokens per prefill (each length pays
``bucket(len) - len``). The geometric default table
(``distribution.sharding.prefill_bucket_table``) halves down from
``cache_len`` — fine for uniform traffic, wasteful under skew (e.g.
chat traffic clustered at short lengths pads up to the next power of
two every time). The fitted table places bucket boundaries on the
observed mass instead.

The top bucket is always ``cache_len`` so every cacheable prompt still
finds a bucket (the engine falls back to exact shapes past the table —
correct but one extra compile per length).

Usage:
  python tools/suggest_buckets.py hist.json --buckets 4 --cache-len 512
  # hist.json: {"12": 830, "13": 411, ...}  (length -> count)

Library use (tests, re-tuning loops):
  from suggest_buckets import suggest_buckets, pad_waste
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterable, Tuple


def _normalize(hist: Dict, cache_len: int) -> Iterable[Tuple[int, int]]:
    """(length, count) pairs, lengths clamped to cache_len (longer
    prompts pad to the full cache anyway), zero counts dropped."""
    merged: Dict[int, int] = {}
    for length, count in hist.items():
        length, count = int(length), int(count)
        if count <= 0 or length <= 0:
            continue
        length = min(length, cache_len)
        merged[length] = merged.get(length, 0) + count
    return sorted(merged.items())


def pad_waste(hist: Dict, table: Tuple[int, ...], cache_len: int) -> int:
    """Total pad tokens the table costs over the histogram (the
    objective ``suggest_buckets`` minimizes)."""
    buckets = sorted(table)
    total = 0
    for length, count in _normalize(hist, cache_len):
        bucket = next((b for b in buckets if b >= length), length)
        total += (bucket - length) * count
    return total


def suggest_buckets(hist: Dict, n_buckets: int,
                    cache_len: int) -> Tuple[int, ...]:
    """Optimal ≤ n_buckets table for the histogram (exact DP).

    Candidate boundaries are the observed lengths plus ``cache_len``
    (an optimal table never puts a boundary where no length ends);
    ``dp[t][j]`` = minimum pad waste covering every length ≤ cand[j]
    with t buckets, the t-th at cand[j]. O(n² · n_buckets) over the
    distinct observed lengths — histogram-sized, not traffic-sized.
    """
    if n_buckets < 1:
        raise ValueError(f"n_buckets must be >= 1, got {n_buckets}")
    pairs = list(_normalize(hist, cache_len))
    if not pairs:
        return (int(cache_len),)
    cands = [length for length, _ in pairs]
    if cands[-1] != cache_len:
        cands.append(cache_len)
    m = len(cands)
    counts = {length: c for length, c in pairs}

    # prefix sums over candidate positions for O(1) segment waste
    w = [counts.get(c, 0) for c in cands]            # count at cand
    wl = [counts.get(c, 0) * c for c in cands]       # count·len at cand
    pw = [0] * (m + 1)
    pwl = [0] * (m + 1)
    for i in range(m):
        pw[i + 1] = pw[i] + w[i]
        pwl[i + 1] = pwl[i] + wl[i]

    def seg(i: int, j: int) -> int:
        """Waste of lengths in cands(i..j] padded to cands[j]
        (i, j are candidate indices; i = -1 means 'from the start')."""
        lo, hi = i + 1, j + 1
        return cands[j] * (pw[hi] - pw[lo]) - (pwl[hi] - pwl[lo])

    INF = float("inf")
    k = min(n_buckets, m)
    dp = [[INF] * m for _ in range(k + 1)]
    back = [[-2] * m for _ in range(k + 1)]
    for j in range(m):
        dp[1][j] = seg(-1, j)
        back[1][j] = -1
    for t in range(2, k + 1):
        for j in range(t - 1, m):
            for i in range(t - 2, j):
                cand = dp[t - 1][i] + seg(i, j)
                if cand < dp[t][j]:
                    dp[t][j] = cand
                    back[t][j] = i
    best_t = min(range(1, k + 1), key=lambda t: dp[t][m - 1])
    table = []
    t, j = best_t, m - 1
    while j >= 0:
        table.append(cands[j])
        j = back[t][j]
        t -= 1
    table = sorted(table)
    if table[-1] != cache_len:      # top bucket always covers the cache
        table[-1] = cache_len
    return tuple(table)


def main() -> int:
    ap = argparse.ArgumentParser(
        description="Fit a prefill bucket table to a prompt-length "
                    "histogram (JSON {length: count}; '-' = stdin)")
    ap.add_argument("histogram", help="path to JSON histogram, or -")
    ap.add_argument("--buckets", type=int, default=4,
                    help="maximum table size (default 4)")
    ap.add_argument("--cache-len", type=int, default=512,
                    help="KV cache length — the forced top bucket")
    args = ap.parse_args()
    if args.histogram == "-":
        hist = json.load(sys.stdin)
    else:
        with open(args.histogram, encoding="utf-8") as f:
            hist = json.load(f)
    table = suggest_buckets(hist, args.buckets, args.cache_len)
    fitted = pad_waste(hist, table, args.cache_len)
    from importlib import import_module
    try:
        shd = import_module("repro.distribution.sharding")
        geo = shd.prefill_bucket_table(args.cache_len, args.buckets)
        geo_waste = pad_waste(hist, geo, args.cache_len)
        print(f"# geometric {geo}: {geo_waste} pad tokens; "
              f"fitted: {fitted} pad tokens", file=sys.stderr)
    except ImportError:
        print(f"# fitted table: {fitted} pad tokens", file=sys.stderr)
    print(",".join(str(b) for b in table))
    return 0


if __name__ == "__main__":
    sys.exit(main())
